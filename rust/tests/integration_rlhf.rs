//! End-to-end RLHF integration tests on the dev artifact bundle: SFT ->
//! proxy RM -> RLHF (sync and async), checking learning signal and the
//! async coordinator's invariants on real executables.

use std::path::PathBuf;

use async_rlhf::config::{Algo, ExpConfig, Mode};
use async_rlhf::coordinator;
use async_rlhf::coordinator::pipeline::staleness_bound_updates;
use async_rlhf::coordinator::trainer::{
    algo_stages_blp, assemble, generate_round, generate_round_staged,
    label_round, make_resident, sample_opts, train_on_batch, BatchSlot,
    LabelScratch, LabelledRound, Round, ROUND_ORIGIN,
};
use async_rlhf::eval::evaluate;
use async_rlhf::gen::fused::FusedEngine;
use async_rlhf::runtime::{ParamView, TrainState};
use async_rlhf::util::rng::Pcg32;

fn dev_available() -> bool {
    let root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    let ok = root.join("dev").join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/dev missing — run `make artifacts`");
    }
    ok
}

fn test_cfg(name: &str) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.model = "dev".into();
    cfg.artifacts_root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    cfg.steps = 10;
    cfg.sft_steps = 80;
    cfg.rm_steps = 60;
    cfg.eval_prompts = 32;
    cfg.run_dir = std::env::temp_dir().join(format!("async_rlhf_test_{name}"));
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    cfg
}

#[test]
fn sft_then_rm_pipeline_learns() {
    if !dev_available() {
        return;
    }
    let cfg = test_cfg("pipeline");
    let prep = coordinator::prepare(&cfg, false).unwrap();
    // SFT should produce a policy that formats responses (some EOS usage):
    let ev = evaluate(
        &prep.engine,
        &prep.sft_params,
        &prep.sft_params,
        &prep.taskgen,
        32,
        0.7,
        1,
    )
    .unwrap();
    assert!(ev.n >= 32);
    assert!(ev.kl_ppl.is_finite() && ev.kl_ppl > 0.5);
    // SFT vs random init: random params should have far lower gold score
    let init = prep.engine.init_policy().unwrap();
    let ev0 = evaluate(
        &prep.engine, &init, &prep.sft_params, &prep.taskgen, 32, 0.7, 1,
    )
    .unwrap();
    assert!(
        ev.mean_gold > ev0.mean_gold,
        "SFT {} vs random {}",
        ev.mean_gold,
        ev0.mean_gold
    );
    // checkpoints are cached: second prepare is instant and identical
    let prep2 = coordinator::prepare(&cfg, false).unwrap();
    assert_eq!(prep.sft_params, prep2.sft_params);
    assert_eq!(prep.rm_params, prep2.rm_params);
}

#[test]
fn sync_dpo_improves_rm_reward() {
    if !dev_available() {
        return;
    }
    let mut cfg = test_cfg("sync_dpo");
    cfg.algo = Algo::Dpo;
    cfg.mode = Mode::Sync;
    cfg.steps = 16;
    cfg.lr = 1e-3;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();
    let series = out.log.series("rm_reward");
    assert!(series.len() >= 8);
    let early: f32 =
        series[..3].iter().map(|(_, v)| v).sum::<f32>() / 3.0;
    let late: f32 = series[series.len() - 3..]
        .iter()
        .map(|(_, v)| v)
        .sum::<f32>()
        / 3.0;
    assert!(
        late > early,
        "RM reward did not improve: early {early} late {late}"
    );
    assert_eq!(out.log.rows.len(), cfg.steps as usize);
    assert_eq!(
        out.episodes,
        cfg.steps * prep.engine.manifest.config.gen_batch as u64
    );
}

#[test]
fn async_matches_sync_and_is_one_step_off_policy() {
    if !dev_available() {
        return;
    }
    let mut sync_cfg = test_cfg("parity");
    sync_cfg.algo = Algo::Dpo;
    sync_cfg.steps = 12;
    sync_cfg.lr = 1e-3;
    let prep = coordinator::prepare(&sync_cfg, false).unwrap();
    let sync_out = coordinator::run(&sync_cfg, &prep, false).unwrap();

    let mut async_cfg = sync_cfg.clone();
    async_cfg.mode = Mode::Async;
    let async_out = coordinator::run(&async_cfg, &prep, false).unwrap();

    // staleness is exactly <= 1 (one-step off-policy, bound-1 queue)
    for row in &async_out.log.rows {
        let st = row.values["staleness"];
        assert!(st <= 1.0 + 1e-6, "staleness {st} > 1 at step {}", row.step);
    }
    // sync is fully on-policy
    for row in &sync_out.log.rows {
        assert_eq!(row.values["staleness"], 0.0);
    }
    // both learn in the same direction (final rm reward within tolerance)
    let s = sync_out.log.recent_mean("rm_reward", 4).unwrap();
    let a = async_out.log.recent_mean("rm_reward", 4).unwrap();
    assert!(
        (s - a).abs() < 1.5,
        "sync {s} vs async {a} diverged beyond tolerance"
    );
    // same episode accounting
    assert_eq!(sync_out.episodes, async_out.episodes);
}

#[test]
fn resident_round_labels_match_host_literal_labels() {
    // Labelling-path equivalence: staging a round's tensors on device once
    // (ResidentRound + logprob_dev + device-input score_rm) must produce
    // labels BITWISE identical to the seed host-literal path — same
    // executables, same input values, different transport. Then the
    // acceptance byte counter: across label + train (PPO layout) the round
    // tokens upload exactly once, under the ROUND_ORIGIN bucket.
    if !dev_available() {
        return;
    }
    let cfg = test_cfg("resident_label");
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let engine = &prep.engine;
    if !engine.manifest.has_artifact("logprob_dev") {
        eprintln!("SKIP: bundle lacks logprob_dev — rebuild artifacts");
        return;
    }
    let mcfg = engine.manifest.config.clone();
    let (b, s) = (mcfg.gen_batch, mcfg.seq_len);
    let generator = FusedEngine::default();
    let mut rng = Pcg32::new(3, 9);
    let round = generate_round(
        engine,
        &generator,
        ParamView::cached("policy", 0, &prep.sft_params),
        0,
        &prep.taskgen,
        0,
        2,
        sample_opts(&cfg),
        &mut rng,
        std::time::Instant::now(),
    )
    .unwrap();

    let mut scratch = LabelScratch::default();
    let baseline = label_round(
        engine, &round, &prep.sft_params, prep.rm_scorer(), 2,
        cfg.eos_penalty, false, &mut scratch, None,
    )
    .unwrap();
    // the fused generate above settled the client capability; on a
    // root-tuple client the resident path stays off by design
    let Some(mut resident) = make_resident(
        engine, &round.gen, None, prep.rm_scorer(), false, true, &mut scratch,
    )
    .unwrap() else {
        eprintln!("SKIP: PJRT client returns root tuples (no zero-copy staging)");
        return;
    };
    let labels = label_round(
        engine, &round, &prep.sft_params, prep.rm_scorer(), 2,
        cfg.eos_penalty, false, &mut scratch, Some(&mut resident),
    )
    .unwrap();
    assert_eq!(baseline.rewards, labels.rewards, "RM scores diverged");
    assert_eq!(baseline.rlp_tok, labels.rlp_tok, "token logprobs diverged");
    assert_eq!(baseline.rlp_seq, labels.rlp_seq, "seq logprobs diverged");
    assert_eq!(baseline.gold_scores, labels.gold_scores);
    assert_eq!(baseline.wins, labels.wins);
    assert_eq!(baseline.ref_ppl, labels.ref_ppl);
    assert_eq!(baseline.mean_blp, labels.mean_blp);
    assert_eq!(baseline.mean_len, labels.mean_len);

    // --- per-round byte counter (ref/rm caches are warm by now) ---
    let mut state = TrainState::new(prep.sft_params.clone());
    engine.reset_stats();
    let mut resident = make_resident(
        engine, &round.gen, None, prep.rm_scorer(), false, true, &mut scratch,
    )
    .unwrap();
    let labels = label_round(
        engine, &round, &prep.sft_params, prep.rm_scorer(), 2,
        cfg.eos_penalty, false, &mut scratch, resident.as_mut(),
    )
    .unwrap();
    let lr = LabelledRound { round, labels, resident };
    let batch = assemble(engine, Algo::Ppo, std::slice::from_ref(&lr), 2).unwrap();
    train_on_batch(engine, &mut state, &batch, 1e-4, 1).unwrap();

    let stats = engine.stats();
    let tensor_bytes = (4 * b * s) as u64; // one [B*S] tensor, i32 or f32
    let up = |k: &str| stats.get(k).map_or(0, |st| st.bytes_up);
    // tokens + resp_mask + blp + rm_mask staged exactly once, under
    // "round" (blp joined the staged set so PPO/RLOO batches reuse it)
    assert_eq!(up(ROUND_ORIGIN), 4 * tensor_bytes, "round staged more than once");
    // labelling re-uploads NOTHING (params are cache hits, inputs shared)
    assert_eq!(up("logprob_dev"), 0, "logprob_dev re-uploaded round tensors");
    assert_eq!(up("score_rm"), 0, "score_rm re-uploaded round tensors");
    // the train batch uploads only rewards (+ 2 scalars) — tokens/mask/
    // blp ride the staged buffers and rlp chains from logprob_dev
    assert_eq!(
        up("train_ppo"),
        (4 * b) as u64 + 8,
        "train_ppo re-uploaded round tensors"
    );
}

/// Clone a round's host data (Round is deliberately not Clone — the two
/// assembly paths under comparison need independent LabelledRounds).
fn clone_round(round: &Round) -> Round {
    Round {
        gen: round.gen.clone(),
        examples: round.examples.clone(),
        start_index: round.start_index,
        params_version: round.params_version,
        tok_version_min: round.tok_version_min,
        tok_version_mean: round.tok_version_mean,
        gen_secs: 0.0,
        gen_span: (0.0, 0.0),
    }
}

#[test]
fn pair_gather_matches_host_assembly_bitwise() {
    // Device-side pair gather vs host assembly: same rounds, same labels,
    // same seeds ⇒ bitwise-identical train metrics AND post-update
    // parameters, for DPO and RLOO at K=2 and the K=4 two-round ladder.
    // The gather permutes the very same values the host path flattens, so
    // any divergence is a transport bug.
    if !dev_available() {
        return;
    }
    let cfg = test_cfg("pair_gather_eq");
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let engine = &prep.engine;
    if !engine.manifest.has_artifact("gather_pairs") {
        eprintln!("SKIP: bundle lacks gather_pairs — rebuild artifacts");
        return;
    }
    let generator = FusedEngine::default();
    let mut scratch = LabelScratch::default();
    let origin = std::time::Instant::now();
    for (algo, k) in [
        (Algo::Dpo, 2usize),
        (Algo::Rloo, 2),
        (Algo::Dpo, 4),
        (Algo::Rloo, 4),
    ] {
        let rpb = async_rlhf::coordinator::trainer::rounds_per_batch(k);
        let mut rng = Pcg32::new(23, k as u64);
        let mut host_rounds = Vec::with_capacity(rpb);
        let mut dev_rounds = Vec::with_capacity(rpb);
        let mut skipped = false;
        for r in 0..rpb {
            let round = generate_round(
                engine,
                &generator,
                ParamView::cached("policy", 0, &prep.sft_params),
                0,
                &prep.taskgen,
                1000 + (r as u64) * 64,
                k,
                sample_opts(&cfg),
                &mut rng,
                origin,
            )
            .unwrap();
            let round2 = clone_round(&round);
            let labels_h = label_round(
                engine, &round, &prep.sft_params, prep.rm_scorer(), k,
                cfg.eos_penalty, false, &mut scratch, None,
            )
            .unwrap();
            let mut resident = make_resident(
                engine, &round.gen, None, prep.rm_scorer(), false,
                algo_stages_blp(algo), &mut scratch,
            )
            .unwrap();
            if resident.is_none() {
                eprintln!("SKIP: PJRT client returns root tuples");
                skipped = true;
                break;
            }
            let labels_d = label_round(
                engine, &round, &prep.sft_params, prep.rm_scorer(), k,
                cfg.eos_penalty, false, &mut scratch, resident.as_mut(),
            )
            .unwrap();
            host_rounds.push(LabelledRound {
                round,
                labels: labels_h,
                resident: None,
            });
            dev_rounds.push(LabelledRound { round: round2, labels: labels_d, resident });
        }
        if skipped {
            return;
        }
        let batch_h = assemble(engine, algo, &host_rounds, k).unwrap();
        let batch_d = assemble(engine, algo, &dev_rounds, k).unwrap();
        // the device batch must actually ride device buffers (rewards are
        // the RLOO family's host tail)
        let n_dev = if algo == Algo::Dpo { 6 } else { 8 };
        assert!(
            batch_d
                .tensors
                .iter()
                .take(n_dev)
                .all(|t| matches!(t, BatchSlot::Device(_))),
            "{algo} k={k}: gather path fell back to host slots"
        );
        let mut state_h = TrainState::new(prep.sft_params.clone());
        let mut state_d = TrainState::new(prep.sft_params.clone());
        let m_h = train_on_batch(engine, &mut state_h, &batch_h, 1e-3, 2).unwrap();
        let m_d = train_on_batch(engine, &mut state_d, &batch_d, 1e-3, 2).unwrap();
        assert_eq!(m_h, m_d, "{algo} k={k}: train metrics diverged");
        assert_eq!(
            state_h.into_params(engine).unwrap(),
            state_d.into_params(engine).unwrap(),
            "{algo} k={k}: post-update parameters diverged"
        );
    }
}

#[test]
fn pair_gather_uploads_index_vector_only() {
    // The acceptance byte counter: on an untupling client a DPO train
    // batch uploads NO [B,S] host tensors — the [2*Bp] pair-index vector
    // (gather_pairs bucket) plus the two train scalars are everything;
    // staging the round costs tokens+mask+rm_mask once under ROUND_ORIGIN
    // (no blp: DPO never reads it). RLOO adds the staged blp tensor and
    // the two [Bp] reward vectors.
    if !dev_available() {
        return;
    }
    let cfg = test_cfg("pair_gather_bytes");
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let engine = &prep.engine;
    if !engine.manifest.has_artifact("gather_pairs") {
        eprintln!("SKIP: bundle lacks gather_pairs — rebuild artifacts");
        return;
    }
    let mcfg = engine.manifest.config.clone();
    let (b, s, bp) = (mcfg.gen_batch, mcfg.seq_len, mcfg.train_pairs);
    let generator = FusedEngine::default();
    let mut scratch = LabelScratch::default();
    let origin = std::time::Instant::now();
    let mut rng = Pcg32::new(29, 1);
    let mut state = TrainState::new(prep.sft_params.clone());
    let tensor_bytes = (4 * b * s) as u64;
    let idx_bytes = (4 * 2 * bp) as u64;

    for (algo, warm) in [(Algo::Dpo, true), (Algo::Rloo, false)] {
        let round = generate_round(
            engine,
            &generator,
            ParamView::cached("policy", 0, &prep.sft_params),
            0,
            &prep.taskgen,
            2000,
            2,
            sample_opts(&cfg),
            &mut rng,
            origin,
        )
        .unwrap();
        if warm {
            // warm the ref/rm caches and the device train state so the
            // measured pass holds steady-state traffic only
            let labels = label_round(
                engine, &round, &prep.sft_params, prep.rm_scorer(), 2,
                cfg.eos_penalty, false, &mut scratch, None,
            )
            .unwrap();
            let lr = LabelledRound {
                round: clone_round(&round),
                labels,
                resident: None,
            };
            let batch = assemble(engine, algo, std::slice::from_ref(&lr), 2).unwrap();
            train_on_batch(engine, &mut state, &batch, 1e-4, 1).unwrap();
        }
        engine.reset_stats();
        let Some(mut resident) = make_resident(
            engine, &round.gen, None, prep.rm_scorer(), false,
            algo_stages_blp(algo), &mut scratch,
        )
        .unwrap() else {
            eprintln!("SKIP: PJRT client returns root tuples");
            return;
        };
        let labels = label_round(
            engine, &round, &prep.sft_params, prep.rm_scorer(), 2,
            cfg.eos_penalty, false, &mut scratch, Some(&mut resident),
        )
        .unwrap();
        let lr = LabelledRound { round, labels, resident: Some(resident) };
        let batch = assemble(engine, algo, std::slice::from_ref(&lr), 2).unwrap();
        train_on_batch(engine, &mut state, &batch, 1e-4, 1).unwrap();

        let stats = engine.stats();
        let up = |k: &str| stats.get(k).map_or(0, |st| st.bytes_up);
        let staged_tensors = if algo_stages_blp(algo) { 4 } else { 3 };
        assert_eq!(
            up(ROUND_ORIGIN),
            staged_tensors * tensor_bytes,
            "{algo}: unexpected round staging traffic"
        );
        assert_eq!(up("gather_pairs"), idx_bytes, "{algo}: gather uploaded more than the index");
        let train_up = up(algo.artifact());
        let expect_train = if algo == Algo::Dpo {
            8 // step + lr scalars
        } else {
            8 + (2 * 4 * bp) as u64 // + the two [Bp] reward vectors
        };
        assert_eq!(train_up, expect_train, "{algo}: train batch uploaded [B,S] host tensors");
        assert_eq!(up("logprob_dev"), 0);
        assert_eq!(up("score_rm"), 0);
    }
}

#[test]
fn pair_gather_sync_round_stages_zero_token_uploads() {
    // Sync-mode chaining: a round generated on the trainer's own engine
    // hands its fused-generate buffers straight into the round staging,
    // so the round's tokens upload ZERO times — total upload traffic for
    // stage+label+assemble+train is the RM validity mask (host-derived),
    // the pair-index vector and the two train scalars.
    if !dev_available() {
        return;
    }
    let cfg = test_cfg("pair_gather_sync");
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let engine = &prep.engine;
    if !engine.manifest.has_artifact("gather_pairs") {
        eprintln!("SKIP: bundle lacks gather_pairs — rebuild artifacts");
        return;
    }
    let mcfg = engine.manifest.config.clone();
    let (b, s, bp) = (mcfg.gen_batch, mcfg.seq_len, mcfg.train_pairs);
    let generator = FusedEngine::default();
    let mut scratch = LabelScratch::default();
    let origin = std::time::Instant::now();
    let mut rng = Pcg32::new(31, 2);
    let mut state = TrainState::new(prep.sft_params.clone());

    // one full warm cycle: settles the untuple capability (first fused
    // call), fills the ref/rm caches and stages the device train state
    let warm = generate_round_staged(
        engine,
        &generator,
        ParamView::cached("policy", 0, &prep.sft_params),
        0,
        &prep.taskgen,
        3000,
        2,
        sample_opts(&cfg),
        &mut rng,
        origin,
    )
    .unwrap();
    let labels = label_round(
        engine, &warm.round, &prep.sft_params, prep.rm_scorer(), 2,
        cfg.eos_penalty, false, &mut scratch, None,
    )
    .unwrap();
    let lr = LabelledRound { round: clone_round(&warm.round), labels, resident: None };
    let batch = assemble(engine, Algo::Dpo, std::slice::from_ref(&lr), 2).unwrap();
    train_on_batch(engine, &mut state, &batch, 1e-4, 1).unwrap();

    let sr = generate_round_staged(
        engine,
        &generator,
        ParamView::cached("policy", 0, &prep.sft_params),
        0,
        &prep.taskgen,
        3064,
        2,
        sample_opts(&cfg),
        &mut rng,
        origin,
    )
    .unwrap();
    let Some(staged) = sr.staged.as_ref() else {
        eprintln!("SKIP: PJRT client returns root tuples (no generate chaining)");
        return;
    };
    engine.reset_stats();
    let mut resident = make_resident(
        engine, &sr.round.gen, Some(staged), prep.rm_scorer(), false,
        algo_stages_blp(Algo::Dpo), &mut scratch,
    )
    .unwrap()
    .expect("untupling client must stage");
    let labels = label_round(
        engine, &sr.round, &prep.sft_params, prep.rm_scorer(), 2,
        cfg.eos_penalty, false, &mut scratch, Some(&mut resident),
    )
    .unwrap();
    let lr = LabelledRound { round: sr.round, labels, resident: Some(resident) };
    let batch = assemble(engine, Algo::Dpo, std::slice::from_ref(&lr), 2).unwrap();
    train_on_batch(engine, &mut state, &batch, 1e-4, 1).unwrap();

    let stats = engine.stats();
    let up = |k: &str| stats.get(k).map_or(0, |st| st.bytes_up);
    let tensor_bytes = (4 * b * s) as u64;
    // ROUND_ORIGIN carries the rm_mask ONLY: tokens/mask/blp chained from
    // the generate buffers, zero uploads
    assert_eq!(up(ROUND_ORIGIN), tensor_bytes, "sync round re-uploaded tokens");
    assert_eq!(up("gather_pairs"), (4 * 2 * bp) as u64);
    assert_eq!(up("train_dpo"), 8);
    // the grand total: mask + index + scalars, nothing else moved up
    assert_eq!(
        engine.transfer_totals().0,
        tensor_bytes + (4 * 2 * bp) as u64 + 8,
        "sync round moved unexpected host→device bytes"
    );
}

#[test]
fn pair_gather_resident_blp_rlp_round_trip() {
    // The staged blp tensor and the chained rlp buffers must read back
    // bitwise-equal to their host-side sources — and the sync-chained
    // generate buffers must mirror the host GenBatch exactly.
    if !dev_available() {
        return;
    }
    let cfg = test_cfg("pair_gather_rt");
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let engine = &prep.engine;
    let generator = FusedEngine::default();
    let mut scratch = LabelScratch::default();
    let origin = std::time::Instant::now();
    let mut rng = Pcg32::new(37, 3);
    let sr = generate_round_staged(
        engine,
        &generator,
        ParamView::cached("policy", 0, &prep.sft_params),
        0,
        &prep.taskgen,
        4000,
        2,
        sample_opts(&cfg),
        &mut rng,
        origin,
    )
    .unwrap();
    let round = sr.round;
    let Some(mut resident) = make_resident(
        engine, &round.gen, None, prep.rm_scorer(), false, true, &mut scratch,
    )
    .unwrap() else {
        eprintln!("SKIP: PJRT client returns root tuples");
        return;
    };
    let labels = label_round(
        engine, &round, &prep.sft_params, prep.rm_scorer(), 2,
        cfg.eos_penalty, false, &mut scratch, Some(&mut resident),
    )
    .unwrap();
    let blp_host: Vec<f32> = round.gen.blp.concat();
    let rt = |buf| engine.download(buf).unwrap().into_f32().unwrap();
    assert_eq!(rt(resident.blp.as_ref().unwrap()), blp_host, "staged blp");
    assert_eq!(
        rt(resident.rlp_tok.as_ref().unwrap()),
        labels.rlp_tok,
        "chained rlp_tok"
    );
    assert_eq!(
        rt(resident.rlp_seq.as_ref().unwrap()),
        labels.rlp_seq,
        "chained rlp_seq"
    );
    // sync-chained generate buffers mirror the host GenBatch bitwise
    if let Some(gb) = &sr.staged {
        let toks_host: Vec<i32> = round.gen.tokens.concat();
        let mask_host: Vec<f32> = round.gen.resp_mask.concat();
        assert_eq!(
            engine.download(&gb.tokens).unwrap().into_i32().unwrap(),
            toks_host,
            "chained tokens"
        );
        assert_eq!(rt(&gb.resp_mask), mask_host, "chained resp_mask");
        assert_eq!(rt(&gb.blp), blp_host, "chained blp");
    }
}

#[test]
fn async_policy_cache_tracks_version_bumps() {
    // Smoke test for device-cache invalidation under publication: the gen
    // worker binds the policy under a bumping version, so after the first
    // step every round must be generated from a *newer* policy than the
    // initial one (staleness exactly 1 in steady state — if the cache
    // served stale params past a version bump, params_version would stop
    // advancing and staleness would grow without bound).
    if !dev_available() {
        return;
    }
    let mut cfg = test_cfg("cache_bump");
    cfg.algo = Algo::Dpo;
    cfg.mode = Mode::Async;
    cfg.steps = 6;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();
    let st: Vec<f32> = out
        .log
        .rows
        .iter()
        .map(|r| r.values["staleness"])
        .collect();
    assert_eq!(st[0], 0.0, "first round is generated from the SFT policy");
    // a cache that served stale params past a version bump would freeze
    // the worker's params_version and staleness would grow without bound
    for (i, &s) in st.iter().enumerate().skip(1) {
        assert!(s <= 1.0, "step {}: staleness {s} (cache went stale?)", i + 1);
    }
    // ...and the rendezvous makes the worker at least one publish behind
    // on some steady-state step, so version bumps were really consumed
    assert!(
        st.iter().any(|&s| s == 1.0),
        "no step consumed a bumped policy version: {st:?}"
    );
}

#[test]
fn staleness_stays_within_queue_bound() {
    // The pipeline invariant on real executables: with queue depth K and
    // M workers, measured per-step staleness never exceeds
    // K * updates_per_batch + updates_per_batch (the satellite formula;
    // == staleness_bound_updates(K, M, T) for the default T=1, M=1) and
    // the first round is always generated from the SFT policy.
    if !dev_available() {
        return;
    }
    for k_bound in [0usize, 1, 2] {
        let mut cfg = test_cfg(&format!("kbound_{k_bound}"));
        cfg.algo = Algo::Dpo;
        cfg.mode = Mode::Async;
        cfg.staleness_bound = k_bound;
        cfg.steps = 8;
        let prep = coordinator::prepare(&cfg, false).unwrap();
        let out = coordinator::run(&cfg, &prep, false).unwrap();
        let bound =
            (k_bound * cfg.updates_per_batch + cfg.updates_per_batch) as f32;
        assert_eq!(
            bound,
            staleness_bound_updates(k_bound, 1, cfg.updates_per_batch) as f32,
            "satellite formula must agree with the helper at T=1, M=1"
        );
        for row in &out.log.rows {
            let st = row.values["staleness"];
            assert!(
                st <= bound + 1e-6,
                "K={k_bound}: staleness {st} > bound {bound} at step {}",
                row.step
            );
        }
        assert_eq!(out.log.rows[0].values["staleness"], 0.0);
        assert_eq!(out.log.rows.len(), cfg.steps as usize);
    }

    // two workers: each adds one in-flight round to the worst case. The
    // M>1 bound assumes fair worker scheduling (a stalled worker's round
    // can age arbitrarily while its sibling feeds the trainer — no fixed
    // assertion is scheduling-robust), so the hard checks here are the
    // structural ones; the fair-scheduling mean is reported like
    // staleness_ladder::sweep reports it, not failed on.
    let mut cfg = test_cfg("kbound_m2");
    cfg.algo = Algo::Dpo;
    cfg.mode = Mode::Async;
    cfg.gen_workers = 2;
    cfg.staleness_bound = 1;
    cfg.steps = 8;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();
    // per-worker generation accounting made it into the log meta
    assert!(out.log.meta.contains_key("gen_rounds_w0"));
    assert!(out.log.meta.contains_key("gen_rounds_w1"));
    assert_eq!(
        out.episodes,
        cfg.steps * prep.engine.manifest.config.gen_batch as u64
    );
    assert_eq!(out.log.rows.len(), cfg.steps as usize);
    let bound = staleness_bound_updates(1, 2, 1) as f32;
    let st: Vec<f32> = out
        .log
        .rows
        .iter()
        .map(|r| r.values["staleness"])
        .collect();
    let mean = st.iter().sum::<f32>() / st.len() as f32;
    if mean > bound {
        eprintln!(
            "WARN: M=2 K=1 mean staleness {mean} > fair-scheduling \
             bound {bound} (a worker stalled): {st:?}"
        );
    }
}

#[test]
fn pipeline_async_default_reproduces_one_step_coordinator() {
    // M=1, K=0 is the pre-refactor Cleanba coordinator: the worker keeps
    // the seed RNG stream (0xa57c) and the rendezvous handover keeps the
    // one-step bound, so equal seeds reproduce the run bitwise given the
    // same handover/publish interleaving. That interleaving is the one
    // scheduler-dependent input (the worker's post-send fetch races the
    // trainer's publish — inherited from the seed coordinator), so the
    // deterministic claim tested here is: identical staleness pattern ⇒
    // bitwise-identical metrics and final parameters.
    if !dev_available() {
        return;
    }
    let mut cfg = test_cfg("pipeline_bitwise");
    cfg.algo = Algo::Dpo;
    cfg.mode = Mode::Async;
    cfg.steps = 6;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let a = coordinator::run(&cfg, &prep, false).unwrap();
    let b = coordinator::run(&cfg, &prep, false).unwrap();
    if a.log.series("staleness") != b.log.series("staleness") {
        // a descheduled worker saw a publish it normally wouldn't —
        // different behaviour-policy schedule, bitwise comparison is
        // meaningless (and would be equally so on the seed coordinator)
        eprintln!("SKIP: scheduler perturbed the rendezvous pattern");
        return;
    }
    for key in ["rm_reward", "win_rate", "kl_ppl", "loss"] {
        assert_eq!(a.log.series(key), b.log.series(key), "{key} diverged");
    }
    assert_eq!(a.final_params, b.final_params, "final params diverged");
    assert_eq!(a.episodes, b.episodes);
}

#[test]
fn ppo_and_rloo_paths_execute() {
    if !dev_available() {
        return;
    }
    for algo in [Algo::Ppo, Algo::Rloo, Algo::Prloo, Algo::Copg, Algo::BestOfN] {
        let mut cfg = test_cfg(&format!("algo_{}", algo.name()));
        cfg.algo = algo;
        cfg.steps = 3;
        let prep = coordinator::prepare(&cfg, false).unwrap();
        let out = coordinator::run(&cfg, &prep, false).unwrap();
        assert_eq!(out.log.rows.len(), 3, "{algo}");
        for row in &out.log.rows {
            assert!(
                row.values["loss"].is_finite(),
                "{algo} produced non-finite loss"
            );
        }
    }
}

#[test]
fn n_minibatches_schedule_counts_and_staleness() {
    if !dev_available() {
        return;
    }
    let mut cfg = test_cfg("n_sched");
    cfg.algo = Algo::Dpo;
    cfg.n_minibatches = 4;
    cfg.steps = 8;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();
    assert_eq!(out.log.rows.len(), 8);
    // within each window of N=4 updates, staleness climbs 0,1,2,3
    let st: Vec<f32> = out
        .log
        .rows
        .iter()
        .map(|r| r.values["staleness"])
        .collect();
    assert_eq!(&st[..4], &[0.0, 1.0, 2.0, 3.0], "staleness ladder: {st:?}");
    assert_eq!(&st[4..8], &[0.0, 1.0, 2.0, 3.0]);
}

#[test]
fn updates_per_batch_multiplies_versions_not_episodes() {
    if !dev_available() {
        return;
    }
    let mut cfg = test_cfg("t_epochs");
    cfg.algo = Algo::Dpo;
    cfg.updates_per_batch = 3;
    cfg.steps = 4;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();
    // episodes: one gen round per step regardless of T
    assert_eq!(
        out.episodes,
        cfg.steps * prep.engine.manifest.config.gen_batch as u64
    );
}

#[test]
fn k4_best_of_k_consumes_two_rounds_per_step() {
    if !dev_available() {
        return;
    }
    let mut cfg = test_cfg("k4");
    cfg.algo = Algo::Dpo;
    cfg.k_samples = 4;
    cfg.steps = 4;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();
    // 2 gen rounds per training step (paper §4.2: gen takes K/2 longer)
    assert_eq!(
        out.episodes,
        cfg.steps * 2 * prep.engine.manifest.config.gen_batch as u64
    );
}
