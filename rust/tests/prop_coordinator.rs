//! Property-based tests on coordinator invariants (DESIGN.md §8), using the
//! in-repo prop-test helper (no proptest in the offline crate set).
//!
//! These run WITHOUT artifacts: they exercise the pure scheduling/assembly
//! logic (prompt duplication, pair selection, episode accounting, schedule
//! partitioning, queue staleness in the clock simulator).

use std::sync::Arc;

use async_rlhf::coordinator::pipeline::{
    cursor_stride, staleness_bound_sharded, staleness_bound_updates, ParamBus,
};
use async_rlhf::coordinator::trainer::{
    best_worst, round_prompts, rounds_per_batch,
};
use async_rlhf::data::{pack_sequence, Task, TaskGen};
use async_rlhf::metrics::Phase;
use async_rlhf::prop_assert;
use async_rlhf::reward::valid_mask;
use async_rlhf::sim::{simulate_async, simulate_sync, StepCosts};
use async_rlhf::util::prop::prop_check;
use async_rlhf::util::rng::Pcg32;

#[test]
fn prompts_are_duplicated_k_times_contiguously() {
    prop_check("round_prompts k-duplication", 100, |rng| {
        let k = if rng.gen_bool(0.5) { 2 } else { 4 };
        let n_prompts = 1 + rng.gen_usize(8);
        let gen_batch = n_prompts * k;
        let taskgen = TaskGen::new(Task::Tldr, 16, 8, rng.next_u64());
        let start = rng.next_u32() as u64;
        let (examples, prompts) = round_prompts(&taskgen, start, gen_batch, k);
        prop_assert!(examples.len() == n_prompts, "examples {}", examples.len());
        prop_assert!(prompts.len() == gen_batch, "prompts {}", prompts.len());
        for (pi, ex) in examples.iter().enumerate() {
            for j in 0..k {
                prop_assert!(
                    prompts[pi * k + j] == ex.prompt,
                    "slot {} not a copy of prompt {pi}",
                    pi * k + j
                );
            }
        }
        Ok(())
    });
}

#[test]
fn pair_gather_best_worst_is_nan_safe() {
    // The trainer's best/worst selection must never panic — a NaN reward
    // (exploding RM, poisoned logprob) is exactly the input that crashed
    // `partial_cmp(..).unwrap()`. With `total_cmp` it stays a total order:
    // indices remain in range, and NaN-free groups agree with the naive
    // float ordering.
    prop_check("best/worst NaN safety", 300, |rng| {
        let k = if rng.gen_bool(0.5) { 2 } else { 4 };
        let groups = 1 + rng.gen_usize(6);
        let mut rewards: Vec<f32> = (0..groups * k)
            .map(|_| (rng.gen_f64() as f32) * 4.0 - 2.0)
            .collect();
        for r in rewards.iter_mut() {
            if rng.gen_bool(0.2) {
                *r = f32::NAN;
            }
        }
        for g in 0..groups {
            let slots = g * k..(g + 1) * k;
            // must not panic, whatever the rewards contain
            let (best, worst) = best_worst(&rewards, slots.clone());
            prop_assert!(
                slots.contains(&best) && slots.contains(&worst),
                "selection out of range: {best}/{worst} vs {slots:?}"
            );
            if rewards[slots.clone()].iter().all(|r| !r.is_nan()) {
                let naive_best = slots
                    .clone()
                    .max_by(|&a, &b| {
                        rewards[a].partial_cmp(&rewards[b]).unwrap()
                    })
                    .unwrap();
                let naive_worst = slots
                    .clone()
                    .min_by(|&a, &b| {
                        rewards[a].partial_cmp(&rewards[b]).unwrap()
                    })
                    .unwrap();
                prop_assert!(
                    rewards[best] == rewards[naive_best]
                        && rewards[worst] == rewards[naive_worst],
                    "NaN-free group diverged from the seed ordering"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn rounds_per_batch_matches_pair_budget() {
    // k completions/prompt: a gen round holds gen_batch/k prompts. One
    // train batch needs train_pairs prompts. With gen_batch = 2 *
    // train_pairs this is exactly k/2 rounds.
    assert_eq!(rounds_per_batch(2), 1);
    assert_eq!(rounds_per_batch(4), 2);
}

#[test]
fn pack_sequence_mask_is_contiguous_response_window() {
    prop_check("pack_sequence mask window", 200, |rng| {
        let p = 2 + rng.gen_usize(20);
        let r = rng.gen_usize(16);
        let s = p + r + 1 + rng.gen_usize(8);
        let prompt: Vec<i32> = (0..p).map(|_| rng.gen_range(60) as i32 + 1).collect();
        let resp: Vec<i32> = (0..r).map(|_| rng.gen_range(60) as i32 + 1).collect();
        let with_eos = rng.gen_bool(0.5);
        let (toks, mask) = pack_sequence(&prompt, &resp, s, with_eos);
        prop_assert!(toks.len() == s && mask.len() == s, "lengths");
        // mask is zero on the prompt, one on the response window, zero after
        for i in 0..p.min(s) {
            prop_assert!(mask[i] == 0.0, "mask on prompt at {i}");
        }
        let expect_ones = (r + usize::from(with_eos)).min(s - p.min(s));
        let ones = mask.iter().filter(|&&m| m == 1.0).count();
        prop_assert!(ones == expect_ones, "ones {ones} != {expect_ones}");
        let first_one = mask.iter().position(|&m| m == 1.0);
        if let Some(f) = first_one {
            prop_assert!(f == p, "window starts at {f} not {p}");
            let last_one = mask.iter().rposition(|&m| m == 1.0).unwrap();
            prop_assert!(
                mask[f..=last_one].iter().all(|&m| m == 1.0),
                "mask not contiguous"
            );
        }
        Ok(())
    });
}

#[test]
fn valid_mask_is_prefix_of_resp_mask_end() {
    prop_check("valid_mask prefix", 200, |rng| {
        let s = 4 + rng.gen_usize(40);
        let p = 1 + rng.gen_usize(s - 2);
        let resp_len = rng.gen_usize(s - p);
        let mut resp_mask = vec![0.0f32; s];
        for m in resp_mask.iter_mut().skip(p).take(resp_len) {
            *m = 1.0;
        }
        let vm = valid_mask(p, &resp_mask);
        // prefix-shaped
        let first_zero = vm.iter().position(|&x| x == 0.0).unwrap_or(s);
        prop_assert!(
            vm[first_zero..].iter().all(|&x| x == 0.0),
            "not prefix-shaped"
        );
        // covers prompt + response exactly
        let expect = p + resp_len;
        let ones = vm.iter().filter(|&&x| x == 1.0).count();
        prop_assert!(ones == expect.max(p), "ones {ones} expect {expect}");
        Ok(())
    });
}

#[test]
fn task_stream_is_pure_in_seed_and_index() {
    prop_check("task stream purity", 60, |rng| {
        let seed = rng.next_u64();
        let task = match rng.gen_usize(3) {
            0 => Task::Tldr,
            1 => Task::Math,
            _ => Task::Chat,
        };
        let g = TaskGen::new(task, 24, 12, seed);
        let i = rng.next_u32() as u64;
        let a = g.example(i);
        // interleave other calls; example(i) must be unaffected
        let _ = g.example(i + 1);
        let _ = g.batch(i + 5, 3);
        let b = g.example(i);
        prop_assert!(a.prompt == b.prompt && a.reference == b.reference,
                     "stream not pure at {i}");
        Ok(())
    });
}

#[test]
fn async_queue_staleness_never_exceeds_one_round() {
    // In the bound-1 queue discrete-event model, the round being trained
    // was generated with params at most 1 version behind: verify via the
    // simulator by checking that generation of round i+1 never starts
    // before round i was handed to the trainer.
    prop_check("bound-1 queue staleness", 100, |rng| {
        let gen = 0.1 + rng.gen_f64() * 5.0;
        let train = 0.1 + rng.gen_f64() * 5.0;
        let score = rng.gen_f64();
        let steps = 5 + rng.gen_usize(40) as u64;
        let costs = StepCosts::new(gen, score, train);
        let sim = simulate_async(&costs, steps);
        let mut gen_spans: Vec<(f64, f64)> = sim
            .timeline
            .spans
            .iter()
            .filter(|s| s.phase == Phase::Generate)
            .map(|s| (s.start, s.end))
            .collect();
        let mut train_spans: Vec<(f64, f64)> = sim
            .timeline
            .spans
            .iter()
            .filter(|s| s.phase == Phase::Train)
            .map(|s| (s.start, s.end))
            .collect();
        gen_spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        train_spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        prop_assert!(gen_spans.len() == steps as usize, "gen spans");
        // round i+1 generation may not start before round i's training
        // start (the trainer "takes" round i, freeing the queue slot)
        for i in 1..gen_spans.len() {
            let gen_start = gen_spans[i].0;
            let train_prev_start = train_spans[i - 1].0 - score;
            prop_assert!(
                gen_start + 1e-9 >= train_prev_start.min(gen_spans[i - 1].1),
                "round {i} generated too early: {gen_start} vs {train_prev_start}"
            );
        }
        // async is never slower than sync on the same costs
        let sync = simulate_sync(&costs, steps);
        prop_assert!(
            sim.wall <= sync.wall + 1e-6,
            "async {} > sync {}",
            sim.wall,
            sync.wall
        );
        Ok(())
    });
}

#[test]
fn async_wall_is_bottleneck_dominated() {
    prop_check("async wall ~ max(gen, trainer)", 100, |rng| {
        let gen = 0.1 + rng.gen_f64() * 4.0;
        let train = 0.1 + rng.gen_f64() * 4.0;
        let steps = 20 + rng.gen_usize(50) as u64;
        let costs = StepCosts::new(gen, 0.0, train);
        let sim = simulate_async(&costs, steps);
        let bottleneck = gen.max(train);
        let lower = bottleneck * steps as f64;
        let upper = lower + gen + train + 1e-6; // pipeline fill/drain
        prop_assert!(
            sim.wall >= lower - 1e-6 && sim.wall <= upper,
            "wall {} outside [{lower}, {upper}]",
            sim.wall
        );
        Ok(())
    });
}

#[test]
fn worker_pool_cursors_partition_prompt_stream() {
    // M pool workers stride the prompt stream: worker w starts at
    // w * stride and hops M * stride per round. Over any number of
    // rounds the consumed index ranges must be disjoint and tile the
    // stream contiguously — no prompt trained twice, none skipped.
    prop_check("worker cursor partition", 100, |rng| {
        let m = 1 + rng.gen_usize(4);
        let k = if rng.gen_bool(0.5) { 2 } else { 4 };
        let n_prompts = 1 + rng.gen_usize(6);
        let gen_batch = (n_prompts * k) as u64;
        let stride = cursor_stride(gen_batch, k);
        prop_assert!(stride == n_prompts as u64, "stride {stride}");
        let rounds = 1 + rng.gen_usize(20);
        let mut seen = std::collections::HashSet::new();
        for w in 0..m {
            let mut cursor = w as u64 * stride;
            for _ in 0..rounds {
                for i in cursor..cursor + stride {
                    prop_assert!(seen.insert(i), "prompt {i} reused (w {w})");
                }
                cursor += stride * m as u64;
            }
        }
        prop_assert!(
            seen.len() as u64 == rounds as u64 * m as u64 * stride,
            "coverage {} != {}",
            seen.len(),
            rounds as u64 * m as u64 * stride
        );
        // contiguous tiling: exactly the first rounds*m*stride indices
        let max = seen.iter().copied().max().unwrap();
        prop_assert!(
            max + 1 == rounds as u64 * m as u64 * stride,
            "stream has holes below {max}"
        );
        Ok(())
    });
}

#[test]
fn admission_streams_partition_prompt_stream_without_drops_or_dups() {
    // Continuous-engine analogue of the cursor partition above: worker w
    // admits prompts one at a time from taskgen.admission(w * stride,
    // stride, m * stride, k). Across M workers the admitted (index, dup)
    // pairs must yield every index exactly k times (dups 0..k in order),
    // with no cross-worker overlap and contiguous tiling of the stream —
    // retirement order inside the pool cannot un-admit anything, so
    // admission-side exactness is the whole no-drop/no-dup invariant.
    prop_check("admission stream partition", 100, |rng| {
        let m = 1 + rng.gen_usize(4);
        let k = if rng.gen_bool(0.5) { 2 } else { 4 };
        let n_prompts = 1 + rng.gen_usize(6);
        let gen_batch = (n_prompts * k) as u64;
        let stride = cursor_stride(gen_batch, k);
        let rounds = 1 + rng.gen_usize(20);
        let per_worker = rounds * n_prompts * k;
        let taskgen = TaskGen::new(Task::Tldr, 16, 8, rng.next_u64());
        let mut counts = std::collections::HashMap::<u64, usize>::new();
        for w in 0..m {
            let mut last: Option<(u64, usize)> = None;
            for a in taskgen
                .admission(w as u64 * stride, stride, stride * m as u64, k)
                .take(per_worker)
            {
                // duplicates of an index arrive consecutively, dup 0..k
                match last {
                    Some((idx, dup)) if idx == a.index => {
                        prop_assert!(
                            a.dup == dup + 1,
                            "dup order broke at index {idx} (w {w})"
                        );
                    }
                    _ => {
                        prop_assert!(
                            a.dup == 0,
                            "index {} began at dup {} (w {w})",
                            a.index,
                            a.dup
                        );
                        if let Some((idx, dup)) = last {
                            prop_assert!(
                                dup == k - 1,
                                "index {idx} left early at dup {dup} (w {w})"
                            );
                        }
                    }
                }
                last = Some((a.index, a.dup));
                let c = counts.entry(a.index).or_insert(0);
                *c += 1;
                prop_assert!(
                    *c <= k,
                    "index {} admitted {} > k times",
                    a.index,
                    *c
                );
            }
        }
        let want = rounds as u64 * m as u64 * stride;
        prop_assert!(
            counts.len() as u64 == want,
            "coverage {} != {want}",
            counts.len()
        );
        prop_assert!(
            counts.values().all(|&c| c == k),
            "some index admitted fewer than k times"
        );
        let max = counts.keys().copied().max().unwrap();
        prop_assert!(max + 1 == want, "stream has holes below {max}");
        Ok(())
    });
}

/// Trainer-side model of the supervisor's lane-ledger protocol
/// (`pipeline::LaneAccounts` in block mode): lane `l` starts at
/// `l * stride`, each accepted block covers `stride` prompts and advances
/// the lane's frontier by `hop = M * stride`. A replayed block (start
/// below the frontier) is dropped and counted; a block past the frontier
/// is a lost round — the loud failure the real trainer bails with.
struct LaneModel {
    stride: u64,
    hop: u64,
    expected: Vec<u64>,
    seen: std::collections::HashSet<u64>,
    dups: u64,
}

impl LaneModel {
    fn new(m: usize, stride: u64) -> Self {
        LaneModel {
            stride,
            hop: stride * m as u64,
            expected: (0..m as u64).map(|l| l * stride).collect(),
            seen: std::collections::HashSet::new(),
            dups: 0,
        }
    }

    /// Ok(true) = fresh block accepted, Ok(false) = duplicate dropped.
    fn accept(&mut self, lane: usize, start: u64) -> Result<bool, String> {
        if start < self.expected[lane] {
            self.dups += 1;
            return Ok(false);
        }
        if start > self.expected[lane] {
            return Err(format!(
                "lane {lane} jumped {} -> {start}: a round was lost",
                self.expected[lane]
            ));
        }
        for i in start..start + self.stride {
            if !self.seen.insert(i) {
                return Err(format!("prompt {i} trained twice"));
            }
        }
        self.expected[lane] += self.hop;
        Ok(true)
    }
}

#[test]
fn worker_respawn_resumes_exact_partition_position() {
    // Supervised-restart invariant: the ledger cursor is advanced only
    // AFTER a round is sent (at-least-once); on a death the supervisor
    // drains the queue into the accounts, then repairs the ledger to the
    // accounts' frontier before respawning, so the replacement re-enters
    // the lane at the exact next block. Whatever the kill schedule —
    // death before the send (regenerate, no drop) or between send and
    // ledger store (drain + repair, no duplicate) — the accepted blocks
    // must tile the lane contiguously.
    prop_check("respawn resumes partition", 200, |rng| {
        let m = 1 + rng.gen_usize(4);
        let stride = 1 + rng.gen_usize(4) as u64;
        let rounds_per_lane = 2 + rng.gen_usize(10) as u64;
        let mut model = LaneModel::new(m, stride);
        let mut ledger: Vec<u64> =
            (0..m as u64).map(|l| l * stride).collect();
        for lane in 0..m {
            let mut accepted = 0u64;
            while accepted < rounds_per_lane {
                let cursor = ledger[lane];
                match rng.gen_usize(4) {
                    // death before the send: nothing delivered, nothing
                    // advanced — the respawn regenerates from `cursor`
                    0 => {}
                    // death between send and ledger store: the queued
                    // round is drained into the accounts, then the
                    // supervisor repairs ledger = max(ledger, expected)
                    1 => {
                        if model.accept(lane, cursor)? {
                            accepted += 1;
                        }
                        ledger[lane] = ledger[lane].max(model.expected[lane]);
                    }
                    // healthy round: send, then advance the ledger; with
                    // a retry-ambiguity replay on top (same block sent
                    // twice) the trainer must drop the second copy
                    _ => {
                        if model.accept(lane, cursor)? {
                            accepted += 1;
                        }
                        ledger[lane] += model.hop;
                        if rng.gen_bool(0.2) {
                            prop_assert!(
                                !model.accept(lane, cursor)?,
                                "replayed block at {cursor} was not dropped"
                            );
                        }
                    }
                }
            }
        }
        // every lane sits exactly rounds_per_lane blocks past its start,
        // and the union of accepted prompts has no holes inside any lane
        for lane in 0..m {
            let want = lane as u64 * stride + rounds_per_lane * model.hop;
            prop_assert!(
                model.expected[lane] == want,
                "lane {lane} frontier {} != {want}",
                model.expected[lane]
            );
        }
        prop_assert!(
            model.seen.len() as u64 == m as u64 * rounds_per_lane * stride,
            "coverage {} != {}",
            model.seen.len(),
            m as u64 * rounds_per_lane * stride
        );
        Ok(())
    });
}

#[test]
fn lane_takeover_restripes_orphans_without_drops_or_dups() {
    // Graceful-degradation invariant: when a worker exhausts its restart
    // budget, its lanes are re-strided onto a survivor, which interleaves
    // the inherited lane with its own by always generating for the lane
    // furthest behind (the supervisor's `pick_lane`). However many
    // workers die and whenever they die, the survivors must keep tiling
    // every lane's arithmetic partition — no orphaned block is skipped,
    // none is generated twice.
    prop_check("lane takeover partition", 200, |rng| {
        let m = 2 + rng.gen_usize(5);
        let stride = 1 + rng.gen_usize(4) as u64;
        let rounds_per_lane = 2 + rng.gen_usize(8) as u64;
        let total = m as u64 * rounds_per_lane;
        let mut model = LaneModel::new(m, stride);
        let mut ledger: Vec<u64> =
            (0..m as u64).map(|l| l * stride).collect();
        // owned[w] = lanes worker w currently serves (starts with its own)
        let mut owned: Vec<Vec<usize>> = (0..m).map(|w| vec![w]).collect();
        let mut alive = vec![true; m];
        let mut accepted = 0u64;
        while accepted < total {
            let live: Vec<usize> =
                (0..m).filter(|&w| alive[w]).collect();
            // maybe kill one (always keep a survivor); with probability
            // 1/2 the victim dies in the send/store window, leaving a
            // drained round for the supervisor to account before repair
            if live.len() > 1 && rng.gen_bool(0.2) {
                let d = live[rng.gen_usize(live.len())];
                if rng.gen_bool(0.5) {
                    if let Some(&l) = owned[d].first() {
                        if model.expected[l]
                            < l as u64 * stride + rounds_per_lane * model.hop
                            && model.accept(l, ledger[l])?
                        {
                            accepted += 1;
                        }
                        ledger[l] = ledger[l].max(model.expected[l]);
                    }
                }
                alive[d] = false;
                let orphans = std::mem::take(&mut owned[d]);
                let heir = *live.iter().find(|&&w| w != d).unwrap();
                // ledger repair precedes the hand-off, as in handle_death
                for &l in &orphans {
                    ledger[l] = ledger[l].max(model.expected[l]);
                }
                owned[heir].extend(orphans);
                continue;
            }
            // a random live worker serves its furthest-behind lane
            let w = live[rng.gen_usize(live.len())];
            let lane = owned[w]
                .iter()
                .copied()
                .min_by_key(|&l| (ledger[l], l))
                .unwrap();
            if model.expected[lane]
                >= lane as u64 * stride + rounds_per_lane * model.hop
            {
                // this lane met its quota; a real worker would keep
                // striding, the model just stops feeding it
                if owned.iter().flatten().all(|&l| {
                    model.expected[l]
                        >= l as u64 * stride + rounds_per_lane * model.hop
                }) {
                    break;
                }
                continue;
            }
            if model.accept(lane, ledger[lane])? {
                accepted += 1;
            }
            ledger[lane] += model.hop;
        }
        prop_assert!(
            model.seen.len() as u64 == total * stride,
            "coverage {} != {} (dups dropped: {})",
            model.seen.len(),
            total * stride,
            model.dups
        );
        for lane in 0..m {
            let want = lane as u64 * stride + rounds_per_lane * model.hop;
            prop_assert!(
                model.expected[lane] == want,
                "lane {lane} frontier {} != {want}",
                model.expected[lane]
            );
        }
        Ok(())
    });
}

/// Position of `idx` within one continuous lane's admission sequence
/// (blocks of `stride` consecutive indices starting at `start`, hopping
/// `hop` between blocks) — the lane-order comparison the frontier/skip
/// protocol is defined over.
fn cont_pos(idx: u64, start: u64, stride: u64, hop: u64) -> u64 {
    let rel = idx - start;
    (rel / hop) * stride + rel % hop
}

/// Successor of `idx` in the same lane sequence.
fn cont_next(idx: u64, start: u64, stride: u64, hop: u64) -> u64 {
    let rel = idx - start;
    if rel % hop + 1 < stride {
        idx + 1
    } else {
        start + (rel / hop + 1) * hop
    }
}

/// Trainer-side accept in the continuous frontier/skip model
/// (`LaneAccounts` in index mode): a delivered index below the frontier
/// or in the skip set is a dropped duplicate; a fresh one lands in the
/// skip set and the frontier advances over every contiguously delivered
/// index. Exactly-once is enforced by the global `seen` set.
#[allow(clippy::too_many_arguments)]
fn cont_accept(
    lane: usize,
    idx: u64,
    stride: u64,
    hop: u64,
    frontier: &mut [u64],
    skip: &mut [std::collections::HashSet<u64>],
    seen: &mut std::collections::HashSet<u64>,
    dups: &mut u64,
) -> Result<(), String> {
    let start = lane as u64 * stride;
    if cont_pos(idx, start, stride, hop)
        < cont_pos(frontier[lane], start, stride, hop)
        || skip[lane].contains(&idx)
    {
        *dups += 1;
        return Ok(());
    }
    if !seen.insert(idx) {
        return Err(format!("prompt {idx} trained twice"));
    }
    skip[lane].insert(idx);
    while skip[lane].remove(&frontier[lane]) {
        frontier[lane] = cont_next(frontier[lane], start, stride, hop);
    }
    Ok(())
}

#[test]
fn continuous_lane_takeover_is_exactly_once_under_kill_schedules() {
    // The continuous engine's takeover invariant: prompts admit one at a
    // time and retire out of admission order, so the trainer's accounts
    // are a per-lane frontier plus a skip set of deliveries above it. A
    // restart-exhausted seat's in-flight KV is abandoned, the queue is
    // drained into the accounts, and a survivor is forcibly retired and
    // respawned over the merged lanes with every cursor rebuilt from
    // (frontier, skip) — re-prefilling abandoned prompts at-least-once
    // while the accounts dedupe to exactly-once. Whatever the kill
    // schedule, every lane's delivered partition must end exact: no
    // hole, no dup.
    prop_check("continuous takeover exactly-once", 150, |rng| {
        let m = 2 + rng.gen_usize(3);
        let stride = 1 + rng.gen_usize(3) as u64;
        let hop = stride * m as u64;
        let blocks = 2 + rng.gen_usize(6) as u64;
        let per_lane = blocks * stride;
        let mut frontier: Vec<u64> =
            (0..m as u64).map(|l| l * stride).collect();
        let mut skip: Vec<std::collections::HashSet<u64>> =
            (0..m).map(|_| Default::default()).collect();
        let mut seen = std::collections::HashSet::new();
        let mut dups = 0u64;
        // seat state: owned lanes with admit cursors, in-flight prompts
        let mut lanes: Vec<Vec<usize>> = (0..m).map(|w| vec![w]).collect();
        let mut cursor: Vec<Vec<u64>> =
            (0..m as u64).map(|w| vec![w * stride]).collect();
        let mut inflight: Vec<Vec<(usize, u64)>> =
            (0..m).map(|_| Vec::new()).collect();
        let mut alive = vec![true; m];
        let mut queue: Vec<(usize, u64)> = Vec::new();
        let mut guard = 0u32;
        while (0..m).any(|l| {
            cont_pos(frontier[l], l as u64 * stride, stride, hop) < per_lane
        }) {
            guard += 1;
            if guard > 200_000 {
                return Err("model stopped making progress".to_string());
            }
            let live: Vec<usize> = (0..m).filter(|&w| alive[w]).collect();
            match rng.gen_usize(10) {
                // admit: a live seat prefills its next undelivered index
                // on a random owned lane (skipping delivered ones, as a
                // respawned seat's rebuilt admission stream does)
                0..=3 => {
                    let w = live[rng.gen_usize(live.len())];
                    if lanes[w].is_empty() {
                        continue;
                    }
                    let j = rng.gen_usize(lanes[w].len());
                    let l = lanes[w][j];
                    let start = l as u64 * stride;
                    let mut idx = cursor[w][j];
                    while cont_pos(idx, start, stride, hop)
                        < cont_pos(frontier[l], start, stride, hop)
                        || skip[l].contains(&idx)
                    {
                        idx = cont_next(idx, start, stride, hop);
                    }
                    if cont_pos(idx, start, stride, hop) < per_lane {
                        inflight[w].push((l, idx));
                        cursor[w][j] = cont_next(idx, start, stride, hop);
                    }
                }
                // retire: an in-flight prompt completes into the queue
                4..=6 => {
                    let w = live[rng.gen_usize(live.len())];
                    if inflight[w].is_empty() {
                        continue;
                    }
                    let i = rng.gen_usize(inflight[w].len());
                    queue.push(inflight[w].swap_remove(i));
                }
                // trainer: accept one queued delivery (any order — the
                // frontier/skip protocol is order-independent)
                7..=8 => {
                    if queue.is_empty() {
                        continue;
                    }
                    let (l, idx) = queue.swap_remove(rng.gen_usize(queue.len()));
                    cont_accept(
                        l, idx, stride, hop, &mut frontier, &mut skip,
                        &mut seen, &mut dups,
                    )?;
                }
                // kill: a restart-exhausted seat dies; drain the queue,
                // abandon in-flight KV (victim's AND the forcibly retired
                // heir's), respawn the heir over the merged lanes from
                // the trainer-accepted frontier + skip set
                _ => {
                    if live.len() < 2 || !rng.gen_bool(0.5) {
                        continue;
                    }
                    let d = live[rng.gen_usize(live.len())];
                    let h = *live.iter().find(|&&w| w != d).unwrap();
                    for (l, idx) in queue.drain(..) {
                        cont_accept(
                            l, idx, stride, hop, &mut frontier, &mut skip,
                            &mut seen, &mut dups,
                        )?;
                    }
                    alive[d] = false;
                    inflight[d].clear();
                    inflight[h].clear();
                    let orphans = std::mem::take(&mut lanes[d]);
                    cursor[d].clear();
                    lanes[h].extend(orphans);
                    cursor[h] =
                        lanes[h].iter().map(|&l| frontier[l]).collect();
                }
            }
        }
        // every lane's frontier sits exactly at its quota with an empty
        // skip set, and the union of trained prompts is the exact
        // arithmetic partition — at-least-once re-prefills became
        // exactly-once deliveries
        for l in 0..m {
            let start = l as u64 * stride;
            prop_assert!(
                frontier[l] == start + blocks * hop,
                "lane {l} frontier {} != {}",
                frontier[l],
                start + blocks * hop
            );
            prop_assert!(
                skip[l].is_empty(),
                "lane {l} left {} deliveries above its frontier",
                skip[l].len()
            );
            let mut idx = start;
            for _ in 0..per_lane {
                prop_assert!(seen.contains(&idx), "lane {l} hole at {idx}");
                idx = cont_next(idx, start, stride, hop);
            }
        }
        prop_assert!(
            seen.len() as u64 == m as u64 * per_lane,
            "coverage {} != {} (dups dropped: {dups})",
            seen.len(),
            m as u64 * per_lane
        );
        Ok(())
    });
}

#[test]
fn staleness_bound_is_monotone_in_queue_workers_and_epochs() {
    // The bound (K + M + 1)·T − 1 (proven for M=1, fair-scheduling for
    // M>1) must grow monotonically in every knob and reduce to the seed
    // coordinator's one-step bound at the defaults.
    prop_check("staleness bound monotone", 100, |rng| {
        let k = rng.gen_usize(8);
        let m = 1 + rng.gen_usize(4);
        let t = 1 + rng.gen_usize(4);
        let b = staleness_bound_updates(k, m, t);
        if t >= 2 {
            prop_assert!(
                b > staleness_bound_updates(k, m, t - 1),
                "not T-monotone"
            );
        }
        prop_assert!(
            staleness_bound_updates(k + 1, m, t) > b,
            "not K-monotone"
        );
        prop_assert!(
            staleness_bound_updates(k, m + 1, t) > b,
            "not M-monotone"
        );
        prop_assert!(
            staleness_bound_updates(0, 1, 1) == 1,
            "K=0 M=1 T=1 must be the one-step bound"
        );
        Ok(())
    });
}

#[test]
fn episode_accounting_partitions_stream() {
    // The RLHF prompt cursor advances gen_batch/k prompts per round; over
    // any number of rounds, prompt index ranges are disjoint and contiguous.
    prop_check("episode partition", 100, |rng| {
        let k = if rng.gen_bool(0.5) { 2 } else { 4 };
        let gen_batch = (1 + rng.gen_usize(8)) * k;
        let rounds = 1 + rng.gen_usize(20);
        let mut cursor = 0u64;
        let mut seen = std::collections::HashSet::new();
        for _ in 0..rounds {
            let n_prompts = (gen_batch / k) as u64;
            for i in cursor..cursor + n_prompts {
                prop_assert!(seen.insert(i), "prompt {i} reused");
            }
            cursor += n_prompts;
        }
        prop_assert!(
            seen.len() == (rounds * gen_batch / k),
            "episodes {} != {}",
            seen.len(),
            rounds * gen_batch / k
        );
        Ok(())
    });
}

#[test]
fn param_bus_subscribers_observe_monotone_untorn_publications() {
    // The publish fan-out invariant every subscriber relies on: whatever
    // the interleaving of a publisher's pointer swaps with concurrent
    // reads, a seat never sees versions go backwards and never sees a
    // torn (version, params) pair. Tearing is made detectable by
    // encoding the version into the payload — params[0] must always
    // equal the version it was published under.
    prop_check("param bus monotone/untorn", 20, |rng| {
        let seats = 1 + rng.gen_usize(4);
        let publishes = 10 + rng.gen_usize(40) as u64;
        let bus = Arc::new(ParamBus::new(seats, 0, Arc::from(vec![0.0f32])));
        let readers: Vec<_> = (0..seats)
            .map(|seat| {
                let bus = bus.clone();
                std::thread::spawn(move || -> Result<(), String> {
                    let mut have = 0u64;
                    while have < publishes {
                        // alternate both read paths under contention
                        let (v, p) = if have % 2 == 0 {
                            bus.latest(seat)
                        } else {
                            match bus.fetch(seat, have) {
                                Some(vp) => vp,
                                None => continue,
                            }
                        };
                        if v < have {
                            return Err(format!(
                                "seat {seat} went backwards: {have} -> {v}"
                            ));
                        }
                        if p[0] != v as f32 {
                            return Err(format!(
                                "seat {seat} torn pair: version {v}, \
                                 payload {}",
                                p[0]
                            ));
                        }
                        have = v;
                    }
                    Ok(())
                })
            })
            .collect();
        for v in 1..=publishes {
            bus.publish(v, Arc::from(vec![v as f32]));
        }
        for (seat, r) in readers.into_iter().enumerate() {
            match r.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => prop_assert!(false, "{e}"),
                Err(_) => prop_assert!(false, "reader {seat} panicked"),
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_staleness_bound_is_base_plus_fan_out_and_monotone() {
    // `staleness_bound_sharded` must reduce exactly to the single-trainer
    // bound at S=1, add exactly the (S-1) fan-out term above it, and be
    // monotone in every knob (the base bound's monotonicity is checked
    // separately above).
    prop_check("sharded bound = base + (s-1)", 200, |rng| {
        let k = rng.gen_usize(8);
        let m = 1 + rng.gen_usize(4);
        let t = 1 + rng.gen_usize(4);
        let s = 1 + rng.gen_usize(6);
        let base = staleness_bound_updates(k, m, t);
        prop_assert!(
            staleness_bound_sharded(k, m, t, 1) == base,
            "S=1 must be the unsharded bound"
        );
        prop_assert!(
            staleness_bound_sharded(k, m, t, s) == base + (s as u64 - 1),
            "fan-out term is not (s-1) at s={s}"
        );
        prop_assert!(
            staleness_bound_sharded(k, m, t, s + 1)
                > staleness_bound_sharded(k, m, t, s),
            "not S-monotone"
        );
        Ok(())
    });
}

/// Deterministic replay: same seed -> identical sampled batch streams.
#[test]
fn rng_streams_replay_exactly() {
    prop_check("rng replay", 50, |rng| {
        let seed = rng.next_u64();
        let mut a = Pcg32::new(seed, 7);
        let mut b = Pcg32::new(seed, 7);
        for _ in 0..100 {
            prop_assert!(a.next_u32() == b.next_u32(), "diverged");
        }
        Ok(())
    });
}
