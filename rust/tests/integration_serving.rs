//! Serve-while-training integration tests on the dev artifact bundle.
//!
//! The acceptance contract for the serving front-end, end to end on real
//! compiled artifacts: (a) with training disabled, traffic replay is
//! bitwise-deterministic at equal seeds; (b) with training on, round
//! staleness stays within the pipeline bound and serving occupancy
//! matches or beats the fixed-round counterfactual under a saturating
//! trace; (c) the exactly-once prompt/session partition survives an
//! injected worker death — respawn completes every turn exactly once,
//! a restart-exhausted seat migrates its sessions onto a survivor, a
//! killed run restarts mid-trace from its checkpoint with `--resume`,
//! and only a pool with no survivors fails loudly naming the sessions
//! that can no longer complete.
//!
//! Requires `make artifacts` (skips, loudly, when artifacts/dev is
//! absent — CI always builds artifacts first).

use std::collections::HashSet;
use std::path::PathBuf;

use async_rlhf::config::{ExpConfig, FaultKind, FaultPlan, GenEngine, Mode};
use async_rlhf::coordinator;
use async_rlhf::coordinator::pipeline::staleness_bound_updates;
use async_rlhf::data::{Task, TaskGen};
use async_rlhf::gen::continuous::{DeviceBackend, PoolCfg};
use async_rlhf::gen::SampleOpts;
use async_rlhf::runtime::{Engine, ParamView};
use async_rlhf::serve::frontend::{run_replay, ServeReport};
use async_rlhf::serve::traffic::{TrafficCfg, TrafficGen};

fn dev_dir() -> Option<PathBuf> {
    let root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    let dir = root.join("dev");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/dev missing — run `make artifacts`");
        None
    }
}

/// A serve-mode config whose trace tiles the dev geometry exactly
/// (gen_batch 8, k 2 -> 4 turns per round; 8 sessions x 2 turns = 4
/// rounds = 4 steps at one round per batch).
fn serve_cfg(name: &str) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.model = "dev".into();
    cfg.artifacts_root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    cfg.mode = Mode::Serve;
    cfg.gen_engine = GenEngine::Continuous;
    cfg.serve_sessions = 8;
    cfg.serve_turns = 2;
    // saturating arrivals: the whole trace is ready almost immediately,
    // so the pool runs full and the occupancy comparison is meaningful
    cfg.arrival_rate = 8.0;
    cfg.sft_steps = 80;
    cfg.rm_steps = 60;
    cfg.eval_prompts = 32;
    cfg.run_dir = std::env::temp_dir().join(format!("async_rlhf_test_{name}"));
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    cfg
}

fn meta_u64(out: &coordinator::RunOutput, key: &str) -> u64 {
    out.log
        .meta
        .get(key)
        .unwrap_or_else(|| panic!("meta '{key}' missing"))
        .parse::<u64>()
        .unwrap_or_else(|e| panic!("meta '{key}' not a count: {e}"))
}

fn meta_f64(out: &coordinator::RunOutput, key: &str) -> f64 {
    out.log
        .meta
        .get(key)
        .unwrap_or_else(|| panic!("meta '{key}' missing"))
        .parse::<f64>()
        .unwrap_or_else(|e| panic!("meta '{key}' not a number: {e}"))
}

/// One training-disabled replay of a 4-session trace on the device
/// backend at fixed params.
fn device_replay(engine: &Engine, params: &[f32], seed: u64) -> ServeReport {
    let mcfg = &engine.manifest.config;
    let taskgen = TaskGen::new(
        Task::from_name(&mcfg.task).unwrap(),
        mcfg.prompt_len,
        mcfg.resp_len,
        seed,
    );
    let traffic = TrafficGen::new(TrafficCfg {
        sessions: 4,
        turns: 2,
        arrival_rate: 0.5,
        seed,
    });
    let mut backend = DeviceBackend::new(engine).expect("device backend");
    run_replay(
        &mut backend,
        &taskgen,
        &traffic,
        PoolCfg {
            slots: mcfg.gen_batch,
            prompt_len: mcfg.prompt_len,
            seq_len: mcfg.seq_len,
            vocab: mcfg.vocab,
            max_cohorts: 4,
            admit_min: 1,
        },
        2,
        SampleOpts { temperature: 0.7, greedy: false },
        ParamView::cached("serve_test", 0, params),
        seed,
        100_000,
    )
    .expect("replay drains")
}

#[test]
fn serving_replay_is_bitwise_deterministic_on_device() {
    // Training disabled, equal seeds: the served completions — and the
    // whole latency trace — must be byte-identical across runs. This is
    // the device-backed face of the scripted-backend determinism test.
    let Some(dir) = dev_dir() else { return };
    let engine = Engine::load(&dir).expect("load dev engine");
    let params = engine.init_policy().expect("init params");

    let a = device_replay(&engine, &params, 42);
    let b = device_replay(&engine, &params, 42);
    assert!(!a.transcript.is_empty());
    assert_eq!(a.transcript, b.transcript, "equal seeds must replay");
    assert_eq!(a.sweeps, b.sweeps);
    assert_eq!(a.ttft, b.ttft);
    assert_eq!(a.retire, b.retire);
    assert_eq!(a.requests, 4 * 2, "every (session, turn) served once");

    // and the seed moves the trace: different arrivals, different runs
    let c = device_replay(&engine, &params, 7);
    assert!(
        c.transcript != a.transcript || c.sweeps != a.sweeps,
        "seed change must move the served trace"
    );
}

#[test]
fn serving_while_training_bounds_staleness_and_occupancy() {
    // The full closed loop: live traffic is the prompt stream, the
    // trainer consumes assembled rounds, and every decode sweep reads
    // the latest published params. Round staleness must stay within the
    // pipeline's queue bound, and continuous serving must not be less
    // slot-efficient than the fixed-round counterfactual it replaces.
    let Some(_dir) = dev_dir() else { return };
    let cfg = serve_cfg("serve_train");
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();

    // trace-derived length: 8 sessions x 2 turns / (8/2) groups = 4
    // rounds = 4 steps; every turn's k candidates trained exactly once
    assert_eq!(out.log.rows.len(), 4, "steps must come from the trace");
    assert_eq!(out.episodes, 4 * 8, "turns trained exactly once");
    assert_eq!(meta_u64(&out, "serve_requests"), 8 * 2);
    assert_eq!(meta_u64(&out, "dropped_duplicate_rounds"), 0);
    assert!(meta_u64(&out, "serve_tokens") > 0);

    let bound = staleness_bound_updates(
        cfg.staleness_bound,
        cfg.gen_workers,
        cfg.updates_per_batch,
    );
    for row in &out.log.rows {
        let stale = row.values["staleness"] as u64;
        assert!(
            stale <= bound,
            "served-round staleness {stale} escaped bound {bound}"
        );
    }
    // per-candidate lag telemetry exists and respects the same bound
    assert!(meta_f64(&out, "serve_lag_max") as u64 <= bound);

    let occ = meta_f64(&out, "serve_occupancy");
    let fixed = meta_f64(&out, "serve_occupancy_round_tier");
    assert!(occ > 0.0 && fixed > 0.0, "occupancy telemetry missing");
    assert!(
        occ >= fixed,
        "continuous serving occupancy {occ:.4} fell below the \
         fixed-round tier {fixed:.4}"
    );
}

#[test]
fn serving_fault_injected_seat_panic_completes_exactly_once() {
    // A scripted panic kills serving seat 0 mid-trace. The supervisor
    // must respawn it with the delivered-turn skip set; the replacement
    // re-serves only the lost in-flight turns, and the trainer's session
    // accounting ends with every turn trained exactly once — no holes,
    // no double-trained rounds.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = serve_cfg("serve_fault");
    cfg.inject_fault = Some(FaultPlan {
        worker: 0,
        round: 1,
        kind: FaultKind::Panic,
    });
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();

    assert_eq!(meta_u64(&out, "worker_restarts"), 1);
    assert_eq!(out.log.rows.len(), 4);
    assert_eq!(out.episodes, 4 * 8, "a turn was dropped or double-trained");
    // retired-but-undelivered turns regenerate after the respawn, so the
    // served count may exceed the trace — never undershoot it
    assert!(meta_u64(&out, "serve_requests") >= 8 * 2);
    let errs = out.log.meta.get("worker_errors").expect("death unrecorded");
    assert!(
        errs.contains("gen-worker-0"),
        "worker_errors does not name the dead seat: {errs}"
    );
}

#[test]
fn serving_unrecoverable_seat_fails_naming_its_sessions() {
    // Zero restarts AND no survivor: with M=1 there is no seat left to
    // migrate the dead seat's sessions onto, so the run must fail loudly
    // naming the seat and its stranded sessions — never hang waiting on
    // turns that will not come, never return a truncated log as success.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = serve_cfg("serve_unrecoverable");
    cfg.max_worker_restarts = 0;
    cfg.inject_fault = Some(FaultPlan {
        worker: 0,
        round: 1,
        kind: FaultKind::Panic,
    });
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let err = coordinator::run(&cfg, &prep, false).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("gen-worker-0"),
        "error does not name the dead seat: {msg}"
    );
    assert!(
        msg.contains("serving sessions"),
        "error does not name the stranded sessions: {msg}"
    );
}

#[test]
fn serving_seat_death_migrates_sessions_to_survivor() {
    // Two serving seats, zero restarts: seat 1 panics with its budget
    // already spent. Instead of failing the run, the supervisor must
    // migrate seat 1's session residue onto seat 0 — which retires,
    // respawns over the merged residues with the delivered-turn skip
    // set, and serves the remainder. Exactly-once accounting holds
    // across the migration and the final transcript covers the whole
    // trace.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = serve_cfg("serve_migrate");
    cfg.gen_workers = 2;
    cfg.max_worker_restarts = 0;
    cfg.inject_fault = Some(FaultPlan {
        worker: 1,
        round: 1,
        kind: FaultKind::Panic,
    });
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();

    assert_eq!(out.log.rows.len(), 4, "the run must complete every step");
    assert_eq!(out.episodes, 4 * 8, "a turn was dropped or double-trained");
    assert_eq!(meta_u64(&out, "worker_restarts"), 0, "budget was zero");
    assert!(
        meta_u64(&out, "sessions_migrated") >= 1,
        "no session migration recorded for the dead seat"
    );
    assert!(
        meta_u64(&out, "degraded_capacity_steps") >= 1,
        "post-death steps must be flagged as degraded-capacity"
    );
    // abandoned-KV telemetry must be present (may be zero if the panic
    // lands between decodes)
    let _ = meta_u64(&out, "inflight_tokens_abandoned");
    let errs = out.log.meta.get("worker_errors").expect("death unrecorded");
    assert!(
        errs.contains("gen-worker-1"),
        "worker_errors does not name the dead seat: {errs}"
    );
    // the survivor's transcript covers the whole trace: every
    // (session, turn) pair served at least once, dead seat's included
    let transcript =
        out.log.meta.get("serve_transcript").expect("transcript missing");
    for s in 0..8u64 {
        for t in 0..2u64 {
            assert!(
                transcript.contains(&format!("session {s} turn {t} ")),
                "turn ({s}, {t}) missing from the migrated transcript"
            );
        }
    }
}

#[test]
fn serving_resume_restarts_mid_trace_exactly_once() {
    // Kill-and-resume for the stateful serve source: an unrecoverable
    // death at round 3 fails the run after the step-3 checkpoint is on
    // disk. `--resume` must rebuild the session boards from the
    // delivered-turn set and serve only the remaining turns — every
    // turn of the trace trained exactly once across the two runs — and
    // a second resume from the same checkpoint must replay the same
    // remainder byte-for-byte (fixed params, fixed seed).
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = serve_cfg("serve_resume");
    cfg.checkpoint_every = 3;
    cfg.max_worker_restarts = 0;
    cfg.inject_fault = Some(FaultPlan {
        worker: 0,
        round: 3,
        kind: FaultKind::Panic,
    });
    let prep = coordinator::prepare(&cfg, false).unwrap();
    // steps 1..=3 train and checkpoint; the seat then dies with no
    // survivor, so the first run fails loudly
    let err = coordinator::run(&cfg, &prep, false).unwrap_err();
    assert!(
        format!("{err:#}").contains("gen-worker-0"),
        "first run must die on the scripted fault: {err:#}"
    );

    let mut cfg2 = cfg.clone();
    cfg2.resume = true;
    cfg2.inject_fault = None;
    let resume_once = || {
        let out = coordinator::run(&cfg2, &prep, false).unwrap();
        assert_eq!(
            out.log.meta.get("resumed_from_step").map(String::as_str),
            Some("3"),
            "must resume from the step-3 checkpoint"
        );
        assert_eq!(out.log.rows.len(), 1, "only step 4 is left to train");
        assert_eq!(
            out.episodes,
            4 * 8,
            "cumulative episodes must cover the whole trace exactly once"
        );
        let transcript = out
            .log
            .meta
            .get("serve_transcript")
            .expect("transcript missing")
            .clone();
        assert_eq!(
            transcript.lines().count(),
            4,
            "resumed run must serve exactly the undelivered remainder"
        );
        transcript
    };
    let t1 = resume_once();
    let t2 = resume_once();
    assert_eq!(
        t1, t2,
        "two resumes from one checkpoint must replay byte-identically"
    );
}

#[test]
fn serving_respawn_skip_set_excludes_delivered_turns() {
    // The respawn contract at the unit seam: a replacement seat's board
    // built from the delivered-turn set schedules only what is left.
    use async_rlhf::serve::session::SessionBoard;
    use async_rlhf::serve::traffic::turn_uid;

    let traffic = TrafficGen::new(TrafficCfg {
        sessions: 4,
        turns: 2,
        arrival_rate: 8.0,
        seed: 42,
    });
    // turn 0 of sessions 0 and 2 already trained before the death
    let delivered: HashSet<u64> =
        [turn_uid(0, 0, 2), turn_uid(2, 0, 2)].into_iter().collect();
    let board = SessionBoard::new(&traffic, 2, 0, 1, &delivered)
        .expect("board with skip set");
    assert!(!board.all_done(), "turn 1s are still owed");
    // sessions with their turn 0 delivered resume at turn 1; the rest
    // start from the top — nothing is re-served, nothing is skipped
    assert_eq!(board.incomplete(), vec![0, 1, 2, 3]);
}
