//! Integration tests over the PJRT runtime + compiled dev artifacts.
//!
//! These exercise the full AOT boundary: HLO text emitted by python is
//! loaded, compiled and executed from Rust, and its numerics are checked
//! against invariants (logprob semantics, decode/full-forward parity
//! through the generation engines, train-step loss descent).
//!
//! Requires `make artifacts` (skips, loudly, when artifacts/dev is absent —
//! CI always builds artifacts first).

use std::path::PathBuf;

use async_rlhf::data::{pack_sequence, Task, TaskGen};
use async_rlhf::gen::{
    cached::CachedEngine, device::DeviceCachedEngine, fused::FusedEngine,
    naive::NaiveEngine, Generator, SampleOpts,
};
use async_rlhf::runtime::{
    scalar_f32, CallArg, DType, Engine, HostTensor, ParamView, TrainState,
};
use async_rlhf::tokenizer as tk;
use async_rlhf::util::rng::Pcg32;

fn dev_dir() -> Option<PathBuf> {
    let root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    let dir = root.join("dev");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/dev missing — run `make artifacts`");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match dev_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

#[test]
fn engine_loads_and_compiles_all_artifacts() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    engine.warmup().unwrap();
    assert!(engine.manifest.param_count > 0);
    assert!(engine.manifest.artifacts.len() >= 12);
}

#[test]
fn call_validates_shapes() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    // wrong input count
    assert!(engine.call("score_rm", &[]).is_err());
    // wrong element count
    let bad = vec![
        HostTensor::F32(vec![0.0; 3]),
        HostTensor::I32(vec![0; 3]),
        HostTensor::F32(vec![0.0; 3]),
    ];
    let err = engine.call("score_rm", &bad).unwrap_err().to_string();
    assert!(err.contains("elements"), "{err}");
    // wrong dtype
    let cfg = &engine.manifest.config;
    let n = engine.manifest.param_count;
    let bad_dtype = vec![
        HostTensor::F32(vec![0.0; n]),
        HostTensor::F32(vec![0.0; cfg.gen_batch * cfg.seq_len]),
        HostTensor::F32(vec![0.0; cfg.gen_batch * cfg.seq_len]),
    ];
    let err = engine.call("score_rm", &bad_dtype).unwrap_err().to_string();
    assert!(err.contains("dtype"), "{err}");
}

#[test]
fn logprob_semantics() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config.clone();
    let params = engine.init_policy().unwrap();
    let (b, s) = (cfg.gen_batch, cfg.seq_len);
    let mut rng = Pcg32::new(3, 0);
    let toks: Vec<i32> = (0..b * s)
        .map(|_| rng.gen_range(cfg.vocab as u32) as i32)
        .collect();
    // full mask vs zero mask
    let full = engine
        .call(
            "logprob",
            &[
                HostTensor::F32(params.clone()),
                HostTensor::I32(toks.clone()),
                HostTensor::F32(vec![1.0; b * s]),
            ],
        )
        .unwrap();
    let seq_lp = full[0].as_f32().unwrap();
    let tok_lp = full[1].as_f32().unwrap();
    for (i, &lp) in seq_lp.iter().enumerate() {
        let sum: f32 = tok_lp[i * s..(i + 1) * s].iter().sum();
        assert!((lp - sum).abs() < 1e-3, "row {i}: {lp} vs {sum}");
        assert!(lp < 0.0);
    }
    // token logprobs are <= 0 and position 0 is 0
    for i in 0..b {
        assert_eq!(tok_lp[i * s], 0.0);
    }
    let zero = engine
        .call(
            "logprob",
            &[
                HostTensor::F32(params),
                HostTensor::I32(toks),
                HostTensor::F32(vec![0.0; b * s]),
            ],
        )
        .unwrap();
    for &lp in zero[0].as_f32().unwrap() {
        assert_eq!(lp, 0.0);
    }
}

#[test]
fn cached_and_naive_engines_emit_identical_sequences() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config.clone();
    let params = engine.init_policy().unwrap();
    let taskgen = TaskGen::new(Task::Tldr, cfg.prompt_len, cfg.resp_len, 7);
    let prompts: Vec<Vec<i32>> = taskgen
        .batch(0, cfg.gen_batch)
        .iter()
        .map(|e| e.prompt.clone())
        .collect();
    let opts = SampleOpts { temperature: 0.7, greedy: false };

    let mut rng1 = Pcg32::new(99, 1);
    let a = CachedEngine::default()
        .generate(&engine, ParamView::fresh(&params), &prompts, opts, &mut rng1)
        .unwrap();
    let mut rng2 = Pcg32::new(99, 1);
    let b = NaiveEngine
        .generate(&engine, ParamView::fresh(&params), &prompts, opts, &mut rng2)
        .unwrap();
    assert_eq!(a.tokens, b.tokens, "engines diverged");
    assert_eq!(a.resp_mask, b.resp_mask);
    assert_eq!(a.terminated, b.terminated);
    for (ra, rb) in a.blp.iter().zip(&b.blp) {
        for (x, y) in ra.iter().zip(rb) {
            assert!((x - y).abs() < 2e-3, "blp diverged: {x} vs {y}");
        }
    }
}

#[test]
fn device_cached_engine_bitwise_matches_literal_cached() {
    // The device-KV tier shares the host RNG stream with the literal
    // cached engine AND executes the same HLO (the *_dev twins alias the
    // tupled artifacts' files), so with equal seeds the sequences, masks
    // and behaviour logprobs must be BITWISE identical — on untupling and
    // fallback PJRT clients alike.
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    if !DeviceCachedEngine::supported(&engine) {
        eprintln!("SKIP: bundle lacks prefill_dev/decode_dev — rebuild artifacts");
        return;
    }
    let cfg = engine.manifest.config.clone();
    let params = engine.init_policy().unwrap();
    let taskgen = TaskGen::new(Task::Tldr, cfg.prompt_len, cfg.resp_len, 7);
    let prompts: Vec<Vec<i32>> = taskgen
        .batch(0, cfg.gen_batch)
        .iter()
        .map(|e| e.prompt.clone())
        .collect();
    let opts = SampleOpts { temperature: 0.7, greedy: false };

    let mut rng1 = Pcg32::new(99, 1);
    let a = CachedEngine::default()
        .generate(&engine, ParamView::cached("p", 0, &params), &prompts, opts, &mut rng1)
        .unwrap();
    let mut rng2 = Pcg32::new(99, 1);
    let b = DeviceCachedEngine::default()
        .generate(&engine, ParamView::cached("p", 0, &params), &prompts, opts, &mut rng2)
        .unwrap();
    assert_eq!(a.tokens, b.tokens, "sequences diverged");
    assert_eq!(a.resp_mask, b.resp_mask);
    assert_eq!(a.blp, b.blp, "behaviour logprobs must be bitwise equal");
    assert_eq!(a.terminated, b.terminated);
    assert_eq!(a.steps, b.steps, "early-exit behaviour diverged");
}

#[test]
fn device_kv_tier_moves_fewer_bytes_than_literal_cached() {
    // Per decoded token the device tier uploads [B] tokens + a scalar and
    // downloads [B, V] logits, while the literal tier round-trips the
    // whole KV cache both ways. Strictly fewer bytes — the acceptance
    // criterion for the third generation tier. Only meaningful on
    // untupling PJRT clients (the fallback host-split degrades chaining
    // to per-step round-trips by design).
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    if !DeviceCachedEngine::supported(&engine) {
        eprintln!("SKIP: bundle lacks prefill_dev/decode_dev — rebuild artifacts");
        return;
    }
    let cfg = engine.manifest.config.clone();
    let params = engine.init_policy().unwrap();
    let taskgen = TaskGen::new(Task::Tldr, cfg.prompt_len, cfg.resp_len, 7);
    let prompts: Vec<Vec<i32>> = taskgen
        .batch(0, cfg.gen_batch)
        .iter()
        .map(|e| e.prompt.clone())
        .collect();
    let opts = SampleOpts { temperature: 0.7, greedy: false };
    let pv = ParamView::cached("p", 0, &params);

    // detect a fallback (root-tuple) client: an untupled execution that
    // downloads anything during the buffer split is not untupling
    let mut prompt_flat = Vec::new();
    for row in &prompts {
        prompt_flat.extend_from_slice(&row[..cfg.prompt_len]);
    }
    engine.reset_stats();
    engine
        .execute_buffers(
            "prefill_dev",
            &[CallArg::Param(pv), CallArg::I32(&prompt_flat)],
        )
        .unwrap();
    let (_, down) = engine.transfer_totals();
    if down > 0 {
        eprintln!("SKIP: PJRT client returns root tuples (no zero-copy chaining)");
        return;
    }

    // warm both paths (compile + param cache), then measure one round each
    let mut rng = Pcg32::new(1, 0);
    CachedEngine::default().generate(&engine, pv, &prompts, opts, &mut rng).unwrap();
    let mut rng = Pcg32::new(1, 0);
    DeviceCachedEngine::default().generate(&engine, pv, &prompts, opts, &mut rng).unwrap();

    engine.reset_stats();
    let mut rng = Pcg32::new(42, 3);
    CachedEngine::default().generate(&engine, pv, &prompts, opts, &mut rng).unwrap();
    let (lit_up, lit_down) = engine.transfer_totals();

    engine.reset_stats();
    let mut rng = Pcg32::new(42, 3);
    DeviceCachedEngine::default().generate(&engine, pv, &prompts, opts, &mut rng).unwrap();
    let (dev_up, dev_down) = engine.transfer_totals();

    // the KV cache dwarfs everything else: the device tier must move
    // strictly fewer bytes in BOTH directions
    assert!(
        dev_up < lit_up && dev_down < lit_down,
        "device tier up/down {dev_up}/{dev_down} not below literal {lit_up}/{lit_down}"
    );
    // and the gap must be at least one KV cache per decoded step
    let kv_bytes = (4 * engine.manifest.kv_cache_len()) as u64;
    assert!(
        lit_up - dev_up >= kv_bytes,
        "literal tier should re-upload the cache at least once per step"
    );
}

#[test]
fn standalone_uploads_and_downloads_are_accounted() {
    // upload_f32 / upload_inputs / upload_arg_as must all surface in
    // CallStats::bytes_up under their origin (the batch-upload paths are
    // exactly where under-reporting would hide the hot-path story), and
    // downloads against the buffer's origin.
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config.clone();
    let (b, s) = (cfg.gen_batch, cfg.seq_len);

    engine.reset_stats();
    let buf = engine.upload_f32("acct", &[0.5f32; 16]).unwrap();
    assert_eq!(engine.stats()["acct"].bytes_up, 64);

    let toks = vec![1i32; b * s];
    let mask = vec![1.0f32; b * s];
    engine
        .upload_inputs(
            "train_sft",
            5,
            &[
                HostTensor::I32(toks.clone()),
                HostTensor::F32(mask.clone()),
            ],
        )
        .unwrap();
    assert_eq!(
        engine.stats()["train_sft"].bytes_up,
        (8 * b * s) as u64,
        "upload_inputs must account both tensors"
    );

    let dev = engine
        .upload_arg_as("round", "logprob", 1, &CallArg::I32(&toks))
        .unwrap();
    assert_eq!(engine.stats()["round"].bytes_up, (4 * b * s) as u64);
    assert_eq!(dev.numel(), b * s);

    engine.download(&buf).unwrap();
    assert_eq!(engine.stats()["acct"].bytes_down, 64);
}

#[test]
fn behaviour_logprobs_match_logprob_executable() {
    // The on-policy invariant for EVERY engine: blp recorded during
    // generation equals the logprob executable's token logprobs on the
    // same sequences (=> IS ratios are exactly 1 on-policy). This is the
    // correctness anchor that also covers the fused on-device sampler.
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config.clone();
    let params = engine.init_policy().unwrap();
    let taskgen = TaskGen::new(Task::Tldr, cfg.prompt_len, cfg.resp_len, 11);
    let prompts: Vec<Vec<i32>> = taskgen
        .batch(0, cfg.gen_batch)
        .iter()
        .map(|e| e.prompt.clone())
        .collect();
    let fused = FusedEngine::default();
    let engines: [&dyn Generator; 3] = [&CachedEngine::default(), &NaiveEngine, &fused];
    for generator in engines {
        let mut rng = Pcg32::new(5, 0);
        let gen = generator
            .generate(
                &engine,
                ParamView::fresh(&params),
                &prompts,
                SampleOpts { temperature: 0.7, greedy: false },
                &mut rng,
            )
            .unwrap();
        let (b, s) = (cfg.gen_batch, cfg.seq_len);
        let mut toks = Vec::with_capacity(b * s);
        let mut mask = Vec::with_capacity(b * s);
        for i in 0..b {
            toks.extend_from_slice(&gen.tokens[i]);
            mask.extend_from_slice(&gen.resp_mask[i]);
        }
        let out = engine
            .call(
                "logprob",
                &[
                    HostTensor::F32(params.clone()),
                    HostTensor::I32(toks),
                    HostTensor::F32(mask.clone()),
                ],
            )
            .unwrap();
        let tok_lp = out[1].as_f32().unwrap();
        let mut checked = 0;
        for i in 0..b {
            for t in 0..s {
                if gen.resp_mask[i][t] == 1.0 {
                    let expect = tok_lp[i * s + t];
                    let got = gen.blp[i][t];
                    assert!(
                        (expect - got).abs() < 2e-3,
                        "{}: row {i} pos {t}: blp {got} vs logprob {expect}",
                        generator.name()
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked > 0, "{}", generator.name());
    }
}

#[test]
fn fused_engine_respects_eos_and_mask_conventions() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config.clone();
    let params = engine.init_policy().unwrap();
    let taskgen = TaskGen::new(Task::Tldr, cfg.prompt_len, cfg.resp_len, 19);
    let prompts: Vec<Vec<i32>> = taskgen
        .batch(0, cfg.gen_batch)
        .iter()
        .map(|e| e.prompt.clone())
        .collect();
    let fused = FusedEngine::default();
    let mut rng = Pcg32::new(2, 0);
    let gen = fused
        .generate(
            &engine,
            ParamView::fresh(&params),
            &prompts,
            SampleOpts { temperature: 0.7, greedy: false },
            &mut rng,
        )
        .unwrap();
    for i in 0..cfg.gen_batch {
        // prompt preserved
        assert_eq!(&gen.tokens[i][..cfg.prompt_len], &prompts[i][..]);
        // mask zero on prompt
        assert!(gen.resp_mask[i][..cfg.prompt_len].iter().all(|&m| m == 0.0));
        // after EOS (in-mask), everything is PAD with zero mask
        if gen.terminated[i] {
            let resp = gen.response(i, cfg.prompt_len);
            assert_eq!(*resp.last().unwrap(), tk::EOS);
            let eos_pos = cfg.prompt_len + resp.len() - 1;
            for t in eos_pos + 1..cfg.seq_len {
                assert_eq!(gen.tokens[i][t], tk::PAD, "row {i} pos {t}");
                assert_eq!(gen.resp_mask[i][t], 0.0);
            }
        }
    }
    // greedy mode is deterministic regardless of seed
    let mut rng_a = Pcg32::new(1, 0);
    let mut rng_b = Pcg32::new(999, 7);
    let greedy = SampleOpts { temperature: 0.7, greedy: true };
    let a = fused
        .generate(&engine, ParamView::fresh(&params), &prompts, greedy, &mut rng_a)
        .unwrap();
    let b = fused
        .generate(&engine, ParamView::fresh(&params), &prompts, greedy, &mut rng_b)
        .unwrap();
    assert_eq!(a.tokens, b.tokens);
}

#[test]
fn sft_train_step_reduces_loss() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config.clone();
    let (b, s) = (cfg.gen_batch, cfg.seq_len);
    let taskgen = TaskGen::new(Task::Tldr, cfg.prompt_len, cfg.resp_len, 13);
    let mut toks = Vec::with_capacity(b * s);
    let mut mask = Vec::with_capacity(b * s);
    for ex in taskgen.batch(0, b) {
        let (t, m) = pack_sequence(&ex.prompt, &ex.reference, s, true);
        toks.extend(t);
        mask.extend(m);
    }
    let mut state = TrainState::new(engine.init_policy().unwrap());
    let mut losses = Vec::new();
    for _ in 0..10 {
        let m = state
            .train_step(
                &engine,
                "train_sft",
                1e-3,
                vec![
                    HostTensor::I32(toks.clone()),
                    HostTensor::F32(mask.clone()),
                ],
            )
            .unwrap();
        losses.push(m[0]);
    }
    assert!(
        losses[9] < losses[0] * 0.9,
        "SFT loss did not descend: {losses:?}"
    );
    assert!(losses.iter().all(|l| l.is_finite()));
    assert_eq!(state.step, 10);
}

#[test]
fn eos_forcing_terminates_generation_early() {
    // A policy SFT'd toward short EOS-terminated outputs should trigger the
    // cached engine's early exit (steps < resp_len). We emulate by packing
    // an extreme logit bias through training: instead, check the mechanism
    // directly — train on responses that are a single EOS.
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config.clone();
    let (b, s) = (cfg.gen_batch, cfg.seq_len);
    let mut state = TrainState::new(engine.init_policy().unwrap());
    let taskgen = TaskGen::new(Task::Tldr, cfg.prompt_len, cfg.resp_len, 17);
    let examples = taskgen.batch(0, b);
    let mut toks = Vec::with_capacity(b * s);
    let mut mask = Vec::with_capacity(b * s);
    for ex in &examples {
        let (t, m) = pack_sequence(&ex.prompt, &[], s, true); // response = EOS only
        toks.extend(t);
        mask.extend(m);
    }
    for _ in 0..30 {
        state
            .train_step(
                &engine,
                "train_sft",
                2e-3,
                vec![
                    HostTensor::I32(toks.clone()),
                    HostTensor::F32(mask.clone()),
                ],
            )
            .unwrap();
    }
    let prompts: Vec<Vec<i32>> =
        examples.iter().map(|e| e.prompt.clone()).collect();
    let trained = state.params_host(&engine).unwrap().to_vec();
    let mut rng = Pcg32::new(1, 1);
    let gen = CachedEngine::default()
        .generate(
            &engine,
            ParamView::fresh(&trained),
            &prompts,
            SampleOpts { temperature: 0.2, greedy: false },
            &mut rng,
        )
        .unwrap();
    assert!(
        gen.steps < cfg.resp_len,
        "no early exit: {} steps",
        gen.steps
    );
    assert!(gen.terminated.iter().filter(|&&t| t).count() > b / 2);
    // terminated rows end with EOS in-mask
    for i in 0..b {
        if gen.terminated[i] {
            let resp = gen.response(i, cfg.prompt_len);
            assert_eq!(*resp.last().unwrap(), tk::EOS);
        }
    }
}

#[test]
fn param_cache_is_bitwise_transparent_and_invalidates_on_version_bump() {
    // Cached-vs-uncached calls must be indistinguishable: same executable,
    // same inputs, so the outputs are bitwise identical whether the params
    // arrive as a fresh literal, a cache miss, or a cache hit. A version
    // bump must actually swap the device-resident contents.
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config.clone();
    let (b, s) = (cfg.gen_batch, cfg.seq_len);
    let params = engine.init_policy().unwrap();
    let toks: Vec<i32> = vec![1; b * s];
    let mask: Vec<f32> = vec![1.0; b * s];
    fn lp(
        engine: &Engine,
        toks: &[i32],
        mask: &[f32],
        pv: ParamView<'_>,
    ) -> Vec<HostTensor> {
        engine
            .call_with(
                "logprob",
                &[CallArg::Param(pv), CallArg::I32(toks), CallArg::F32(mask)],
            )
            .unwrap()
    }
    let fresh = lp(&engine, &toks, &mask, ParamView::fresh(&params));
    let miss = lp(&engine, &toks, &mask, ParamView::cached("t", 0, &params));
    let hit = lp(&engine, &toks, &mask, ParamView::cached("t", 0, &params));
    assert_eq!(fresh[0].as_f32().unwrap(), miss[0].as_f32().unwrap());
    assert_eq!(miss[0].as_f32().unwrap(), hit[0].as_f32().unwrap());
    assert_eq!(miss[1].as_f32().unwrap(), hit[1].as_f32().unwrap());
    let (hits, misses) = engine.param_cache_counters();
    assert_eq!((hits, misses), (1, 1), "one miss then one hit");

    // version bump with different content: the cache must re-upload, and
    // the result must match an uncached call with the new params
    let params2 = engine.init_rm().unwrap();
    assert_ne!(params, params2);
    let bumped = lp(&engine, &toks, &mask, ParamView::cached("t", 1, &params2));
    let direct = lp(&engine, &toks, &mask, ParamView::fresh(&params2));
    assert_eq!(bumped[0].as_f32().unwrap(), direct[0].as_f32().unwrap());
    assert_ne!(
        bumped[0].as_f32().unwrap(),
        hit[0].as_f32().unwrap(),
        "version bump must not serve stale params"
    );
    let (_, misses) = engine.param_cache_counters();
    assert_eq!(misses, 2, "version bump is a miss");

    // explicit invalidation: same (key, version), new content
    engine.invalidate_params("t");
    let after_inval = lp(&engine, &toks, &mask, ParamView::cached("t", 1, &params));
    assert_eq!(after_inval[0].as_f32().unwrap(), fresh[0].as_f32().unwrap());
}

#[test]
fn device_resident_train_matches_host_literal_path() {
    // Engine-equivalence invariant, extended to the buffer path: the
    // device-resident TrainState (params/m/v never leave the device,
    // batch uploaded once) must produce bitwise-identical metrics and
    // final params to the seed-style full host round-trip through call().
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config.clone();
    let (b, s) = (cfg.gen_batch, cfg.seq_len);
    let taskgen = TaskGen::new(Task::Tldr, cfg.prompt_len, cfg.resp_len, 23);
    let mut toks = Vec::with_capacity(b * s);
    let mut mask = Vec::with_capacity(b * s);
    for ex in taskgen.batch(0, b) {
        let (t, m) = pack_sequence(&ex.prompt, &ex.reference, s, true);
        toks.extend(t);
        mask.extend(m);
    }
    let n = engine.manifest.param_count;

    // seed path: host params/m/v threaded through every call
    let mut p = engine.init_policy().unwrap();
    let mut m = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    let mut host_metrics = Vec::new();
    for step in 1..=5 {
        let out = engine
            .call(
                "train_sft",
                &[
                    HostTensor::F32(p.clone()),
                    HostTensor::F32(m.clone()),
                    HostTensor::F32(v.clone()),
                    scalar_f32(step as f32),
                    scalar_f32(1e-3),
                    HostTensor::I32(toks.clone()),
                    HostTensor::F32(mask.clone()),
                ],
            )
            .unwrap();
        let mut it = out.into_iter();
        p = it.next().unwrap().into_f32().unwrap();
        m = it.next().unwrap().into_f32().unwrap();
        v = it.next().unwrap().into_f32().unwrap();
        host_metrics.push(it.next().unwrap().into_f32().unwrap());
    }

    // buffer path: batch uploaded once, triple device-resident throughout
    let mut state = TrainState::new(engine.init_policy().unwrap());
    let batch = vec![HostTensor::I32(toks), HostTensor::F32(mask)];
    let dev_batch = engine.upload_inputs("train_sft", 5, &batch).unwrap();
    let mut dev_metrics = Vec::new();
    for _ in 0..5 {
        dev_metrics.push(
            state
                .train_step_uploaded(&engine, "train_sft", 1e-3, &dev_batch)
                .unwrap(),
        );
    }
    assert_eq!(host_metrics, dev_metrics, "metrics diverged across paths");
    assert_eq!(
        state.params_host(&engine).unwrap(),
        &p[..],
        "final params diverged across paths"
    );
}

#[test]
fn train_state_scalar_plumbing() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    // step scalar is 1-based and lr is passed through: two steps with lr=0
    // must not change params
    let params = engine.init_policy().unwrap();
    let cfg = engine.manifest.config.clone();
    let (b, s) = (cfg.gen_batch, cfg.seq_len);
    let mut state = TrainState::new(params.clone());
    for _ in 0..2 {
        state
            .train_step(
                &engine,
                "train_sft",
                0.0,
                vec![
                    HostTensor::I32(vec![1; b * s]),
                    HostTensor::F32(vec![1.0; b * s]),
                ],
            )
            .unwrap();
    }
    assert_eq!(
        state.params_host(&engine).unwrap(),
        &params[..],
        "lr=0 must be a no-op on params"
    );
    let _ = scalar_f32(0.0);
}

#[test]
fn pair_gather_manifest_entry_parses_and_executes() {
    // The gather_pairs manifest entry written by aot.py must round-trip
    // through the Rust manifest parser (untupled flag, 11-input/12-output
    // signature, index-vector shape) and the executable must really
    // permute rows: marker tensors come back in pair-index order on both
    // the per-side and the stacked outputs.
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config.clone();
    let (bg, s, bp) = (cfg.gen_batch, cfg.seq_len, cfg.train_pairs);
    let spec = engine.manifest.artifact("gather_pairs").unwrap().clone();
    assert!(spec.untupled, "gather_pairs must run on the buffer path");
    assert_eq!(spec.inputs.len(), 11);
    assert_eq!(spec.outputs.len(), 12);
    assert_eq!(spec.inputs[10].shape, vec![2 * bp], "pair index vector");
    assert_eq!(spec.inputs[10].dtype, DType::I32);
    assert_eq!(spec.inputs[0].numel(), bg * s);
    assert_eq!(spec.outputs[0].numel(), bp * s, "side outputs are [Bp,S]");
    assert_eq!(spec.outputs[8].numel(), bp, "rseq outputs are [Bp]");
    assert_eq!(spec.outputs[10].numel(), 2 * bp * s, "stacked is [2Bp,S]");

    // marker rows: round-a row i holds value i, round-b row i holds Bg+i
    let row_marked_i32 = |base: i32| -> Vec<i32> {
        (0..bg * s).map(|j| base + (j / s) as i32).collect()
    };
    let row_marked_f32 = |base: f32| -> Vec<f32> {
        (0..bg * s).map(|j| base + (j / s) as f32).collect()
    };
    let rseq_a: Vec<f32> = (0..bg).map(|i| i as f32).collect();
    let rseq_b: Vec<f32> = (0..bg).map(|i| (bg + i) as f32).collect();
    let mut idx: Vec<i32> = (0..2 * bp as i32).rev().collect(); // any permutation
    idx[0] = (2 * bg - 1) as i32; // reach into round b's last row
    let tok_a = row_marked_i32(0);
    let tok_b = row_marked_i32(bg as i32);
    let f_a = row_marked_f32(0.0);
    let f_b = row_marked_f32(bg as f32);
    let out = engine
        .execute_buffers(
            "gather_pairs",
            &[
                CallArg::I32(&tok_a),
                CallArg::F32(&f_a),
                CallArg::F32(&f_a),
                CallArg::F32(&f_a),
                CallArg::F32(&rseq_a),
                CallArg::I32(&tok_b),
                CallArg::F32(&f_b),
                CallArg::F32(&f_b),
                CallArg::F32(&f_b),
                CallArg::F32(&rseq_b),
                CallArg::I32(&idx),
            ],
        )
        .unwrap();
    let tok1 = engine.download(&out[0]).unwrap().into_i32().unwrap();
    let tok2 = engine.download(&out[2]).unwrap().into_i32().unwrap();
    let rseq1 = engine.download(&out[8]).unwrap().into_f32().unwrap();
    let tok_all = engine.download(&out[10]).unwrap().into_i32().unwrap();
    for (p, &want) in idx[..bp].iter().enumerate() {
        assert!(tok1[p * s..(p + 1) * s].iter().all(|&t| t == want));
        assert_eq!(rseq1[p], want as f32);
    }
    for (p, &want) in idx[bp..].iter().enumerate() {
        assert!(tok2[p * s..(p + 1) * s].iter().all(|&t| t == want));
    }
    for (r, &want) in idx.iter().enumerate() {
        assert!(tok_all[r * s..(r + 1) * s].iter().all(|&t| t == want));
    }
}
