"""AOT pipeline tests: artifact emission, manifest integrity, HLO validity."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, configs, model

CFG = configs.CONFIGS["dev"]


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts") / "dev"
    manifest = aot.build_config(CFG, str(out), verbose=False)
    return str(out), manifest


def test_all_artifacts_emitted(built):
    out, manifest = built
    for name, art in manifest["artifacts"].items():
        path = os.path.join(out, art["file"])
        assert os.path.exists(path), f"missing {name}"
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"


def test_manifest_schema(built):
    out, manifest = built
    # round-trips through JSON
    loaded = json.loads(open(os.path.join(out, "manifest.json")).read())
    assert loaded["param_count"] == configs.param_count(CFG)
    assert loaded["config"]["name"] == "dev"
    expected = {
        "prefill", "decode", "generate", "forward_full", "logprob",
        "score_rm", "gather_pairs", "train_sft", "train_rm", "train_dpo",
        "train_ppo", "train_rloo", "train_prloo", "train_copg", "train_bon",
        "prefill_dev", "decode_dev", "logprob_dev",
    }
    assert set(loaded["artifacts"]) == expected
    for name, art in loaded["artifacts"].items():
        assert art["inputs"], name
        assert art["outputs"], name
        for io in art["inputs"] + art["outputs"]:
            assert io["dtype"] in ("f32", "i32")


def test_train_steps_have_optimizer_signature(built):
    _, manifest = built
    for name, art in manifest["artifacts"].items():
        if not name.startswith("train_"):
            continue
        names = [i["name"] for i in art["inputs"]]
        assert names[:5] == ["params", "m", "v", "step", "lr"], name
        # outputs: params', m', v', metrics
        out_shapes = [tuple(o["shape"]) for o in art["outputs"]]
        n = manifest["param_count"]
        assert out_shapes[:3] == [(n,), (n,), (n,)], name
        assert out_shapes[3] == (8,), name


def test_init_params_written(built):
    out, manifest = built
    pol = np.load(os.path.join(out, "init_policy.npy"))
    rm = np.load(os.path.join(out, "init_rm.npy"))
    assert pol.shape == (manifest["param_count"],)
    assert rm.shape == (manifest["param_count"],)
    assert pol.dtype == np.float32
    assert not np.array_equal(pol, rm)  # distinct seeds


def test_bon_aliases_sft(built):
    _, manifest = built
    assert (manifest["artifacts"]["train_bon"]["file"]
            == manifest["artifacts"]["train_sft"]["file"])


def test_dev_twins_alias_tupled_namesakes(built):
    """The buffer-path twins must be the SAME computation as their tupled
    namesakes (same HLO file, same I/O signature) with only the untupled
    protocol flag flipped — that is what makes the DeviceCachedEngine's
    bitwise-equivalence to the literal CachedEngine provable."""
    _, manifest = built
    for base in ["prefill", "decode", "logprob"]:
        tupled = manifest["artifacts"][base]
        twin = manifest["artifacts"][f"{base}_dev"]
        assert twin["file"] == tupled["file"], base
        assert twin["inputs"] == tupled["inputs"], base
        assert twin["outputs"] == tupled["outputs"], base
        assert len(twin["outputs"]) >= 2, base
        assert twin["untupled"] and not tupled["untupled"], base
    # score_rm has a single output: the untupled protocol cannot represent
    # it (1-leaf result is ambiguous with a fallback client's root tuple)
    assert not manifest["artifacts"]["score_rm"]["untupled"]


def test_gather_pairs_registered_untupled(built):
    """The pair-gather artifact must run on the buffer path (untupled, so
    its train-layout outputs stay device-resident) and its manifest entry
    must carry the exact shapes the Rust runtime validates against —
    keys/dtypes here mirror what runtime/manifest.rs parses, so a schema
    drift fails on this side before it crashes PJRT on that side."""
    out, manifest = built
    art = manifest["artifacts"]["gather_pairs"]
    assert art["untupled"]
    assert len(art["outputs"]) >= 2  # untupled protocol requirement
    bg, s, bp = CFG.gen_batch, CFG.seq_len, CFG.train_pairs
    ins = {i["name"]: (tuple(i["shape"]), i["dtype"]) for i in art["inputs"]}
    assert ins["pair_idx"] == ((2 * bp,), "i32")
    for side in "ab":
        assert ins[f"tok_{side}"] == ((bg, s), "i32")
        for t in ["mask", "blp", "rlp"]:
            assert ins[f"{t}_{side}"] == ((bg, s), "f32")
        assert ins[f"rseq_{side}"] == ((bg,), "f32")
    out_shapes = [tuple(o["shape"]) for o in art["outputs"]]
    # 4 pair-side [Bp,S] token/mask + 4 blp/rlp, 2 [Bp] rseq, 2 [2Bp,S]
    assert out_shapes == [(bp, s)] * 8 + [(bp,)] * 2 + [(2 * bp, s)] * 2
    # the JSON round-trips and the runtime-critical keys survive it
    loaded = json.loads(open(os.path.join(out, "manifest.json")).read())
    assert loaded["artifacts"]["gather_pairs"]["untupled"] is True
    assert loaded["artifacts"]["gather_pairs"]["inputs"] == art["inputs"]


def test_hlo_text_parses_back(built):
    """The emitted text must parse back into an HLO module (the Rust runtime
    does the same via `HloModuleProto::from_text_file`; end-to-end execution
    is covered by the Rust integration tests)."""
    out, manifest = built
    from jax._src.lib import xla_client as xc

    for name, art in manifest["artifacts"].items():
        text = open(os.path.join(out, art["file"])).read()
        mod = xc._xla.hlo_module_from_text(text)
        proto = mod.as_serialized_hlo_module_proto()
        assert len(proto) > 0, name
