"""L2 loss properties: gradient directions, invariances, optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, losses, model, optim

CFG = configs.CONFIGS["dev"]
Bp, Bg, S, P = CFG.train_pairs, CFG.gen_batch, CFG.seq_len, CFG.prompt_len


@pytest.fixture(scope="module")
def flat():
    return jnp.asarray(model.init_params(CFG, 42)) * 5.0


def _toks(seed, b):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(1, CFG.vocab, (b, S)), jnp.int32)


def _resp_mask(b):
    m = jnp.zeros((b, S), jnp.float32)
    return m.at[:, P:].set(1.0)


# --- SFT -------------------------------------------------------------------

def test_sft_loss_positive_and_decreases(flat):
    toks, mask = _toks(0, Bg), _resp_mask(Bg)
    step = optim.make_train_step(CFG, losses.sft)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    f = flat
    metrics = []
    for i in range(8):
        f, m, v, met = step(f, m, v, jnp.float32(i + 1), jnp.float32(1e-3),
                            toks, mask)
        metrics.append(float(met[0]))
    assert metrics[0] > 0
    assert metrics[-1] < metrics[0]


def test_sft_mask_zero_gives_zero_grad(flat):
    toks = _toks(1, Bg)
    mask = jnp.zeros((Bg, S), jnp.float32)
    g = jax.grad(lambda p: losses.sft(CFG, p, toks, mask)[0])(flat)
    np.testing.assert_allclose(g, 0.0, atol=1e-8)


# --- DPO -------------------------------------------------------------------

def test_dpo_gradient_direction(flat):
    """A DPO step must raise logprob of chosen relative to rejected."""
    tp, tn, mask = _toks(2, Bp), _toks(3, Bp), _resp_mask(Bp)
    rlp_p, _ = model.seq_logprob(CFG, flat, tp, mask)
    rlp_n, _ = model.seq_logprob(CFG, flat, tn, mask)
    lp_p0, _ = model.seq_logprob(CFG, flat, tp, mask)
    lp_n0, _ = model.seq_logprob(CFG, flat, tn, mask)
    step = optim.make_train_step(CFG, losses.online_dpo, {"beta": 0.1})
    f, m, v = flat, jnp.zeros_like(flat), jnp.zeros_like(flat)
    for i in range(3):
        f, m, v, _ = step(f, m, v, jnp.float32(i + 1), jnp.float32(1e-3),
                          tp, mask, tn, mask, rlp_p, rlp_n)
    lp_p1, _ = model.seq_logprob(CFG, f, tp, mask)
    lp_n1, _ = model.seq_logprob(CFG, f, tn, mask)
    margin0 = (lp_p0 - lp_n0).mean()
    margin1 = (lp_p1 - lp_n1).mean()
    assert margin1 > margin0


def test_dpo_loss_at_init_is_log2(flat):
    """With identical policies (ref == policy), margin = 0 -> loss = ln 2."""
    tp, tn, mask = _toks(4, Bp), _toks(5, Bp), _resp_mask(Bp)
    rlp_p, _ = model.seq_logprob(CFG, flat, tp, mask)
    rlp_n, _ = model.seq_logprob(CFG, flat, tn, mask)
    loss, metrics = losses.online_dpo(
        CFG, flat, tp, mask, tn, mask, rlp_p, rlp_n, 0.1
    )
    np.testing.assert_allclose(float(loss), np.log(2.0), rtol=1e-5)


# --- RLOO family -----------------------------------------------------------

def _rloo_batch(flat, seed):
    t1, t2, mask = _toks(seed, Bp), _toks(seed + 1, Bp), _resp_mask(Bp)
    _, blp1 = model.seq_logprob(CFG, flat, t1, mask)
    _, blp2 = model.seq_logprob(CFG, flat, t2, mask)
    rng = np.random.default_rng(seed)
    r1 = jnp.asarray(rng.normal(0, 1, Bp), jnp.float32)
    r2 = jnp.asarray(rng.normal(0, 1, Bp), jnp.float32)
    return t1, mask, t2, mask, blp1, blp2, blp1, blp2, r1, r2


def test_rloo_advantages_antisymmetric():
    r1 = jnp.asarray([1.0, 2.0])
    r2 = jnp.asarray([0.5, 3.0])
    z = jnp.zeros((2, 4))
    a1, a2 = losses._rloo_adv(r1, r2, z, z, z, z, 0.05)
    np.testing.assert_allclose(a1, -a2)
    np.testing.assert_allclose(a1, r1 - r2)


def test_rloo_and_copg_gradients_match(flat):
    """Paper App. B: CoPG has the *same gradient* as vanilla RLOO
    (log pi_old is a constant shift)."""
    batch = _rloo_batch(flat, 10)
    g1 = jax.grad(lambda p: losses.rloo(CFG, p, *batch, beta=0.05)[0])(flat)
    g2 = jax.grad(lambda p: losses.copg(CFG, p, *batch, beta=0.05)[0])(flat)
    np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-4)


def test_prloo_equals_rloo_on_policy_grad(flat):
    """On-policy (behaviour == current), ratio == 1: Proximal RLOO's
    gradient reduces to ratio * grad(logprob) * A = RLOO's gradient."""
    batch = _rloo_batch(flat, 20)
    g_pr = jax.grad(
        lambda p: losses.proximal_rloo(CFG, p, *batch, beta=0.05, clip=0.2)[0]
    )(flat)
    g_rl = jax.grad(lambda p: losses.rloo(CFG, p, *batch, beta=0.05)[0])(flat)
    np.testing.assert_allclose(g_pr, g_rl, atol=1e-4, rtol=1e-3)


def test_prloo_clipping_bounds_offpolicy_update(flat):
    """Off-policy with huge advantage, the clipped objective's gradient
    magnitude must not exceed the unclipped one."""
    t1, mask, t2, _, blp1, blp2, rlp1, rlp2, _, _ = _rloo_batch(flat, 30)
    # Make the data strongly off-policy: pretend behaviour logprobs were
    # much higher than the current policy's.
    blp1_off = blp1 + 0.5 * mask
    blp2_off = blp2 + 0.5 * mask
    r1 = jnp.full((Bp,), 5.0)
    r2 = jnp.zeros((Bp,))
    args = (t1, mask, t2, mask, blp1_off, blp2_off, rlp1, rlp2, r1, r2)
    g_clip = jax.grad(
        lambda p: losses.proximal_rloo(CFG, p, *args, beta=0.0, clip=0.2)[0]
    )(flat)
    g_noclip = jax.grad(
        lambda p: losses.proximal_rloo(CFG, p, *args, beta=0.0, clip=1e9)[0]
    )(flat)
    assert jnp.linalg.norm(g_clip) <= jnp.linalg.norm(g_noclip) * 1.001


# --- PPO ---------------------------------------------------------------

def _ppo_batch(flat, seed):
    toks, mask = _toks(seed, Bg), _resp_mask(Bg)
    _, blp = model.seq_logprob(CFG, flat, toks, mask)
    rng = np.random.default_rng(seed)
    rewards = jnp.asarray(rng.normal(0, 1, Bg), jnp.float32)
    return toks, mask, blp, blp, rewards


def test_ppo_runs_and_is_finite(flat):
    batch = _ppo_batch(flat, 40)
    loss, metrics = losses.ppo(CFG, flat, *batch, beta=0.05, clip=0.2,
                               gamma=1.0, lam=0.95, vf_coef=0.1)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(metrics)).all()
    # on-policy: ratio == 1 and approx_kl == 0
    np.testing.assert_allclose(float(metrics[6]), 1.0, atol=1e-5)
    np.testing.assert_allclose(float(metrics[3]), 0.0, atol=1e-5)


def test_ppo_improves_reward_on_bandit_like_batch(flat):
    """Sequences with reward +1 should gain logprob over ones with -1."""
    toks, mask = _toks(41, Bg), _resp_mask(Bg)
    _, blp = model.seq_logprob(CFG, flat, toks, mask)
    rewards = jnp.asarray([1.0, -1.0] * (Bg // 2), jnp.float32)
    step = optim.make_train_step(
        CFG, losses.ppo,
        {"beta": 0.0, "clip": 0.2, "gamma": 1.0, "lam": 0.95, "vf_coef": 0.1},
    )
    f, m, v = flat, jnp.zeros_like(flat), jnp.zeros_like(flat)
    for i in range(4):
        f, m, v, _ = step(f, m, v, jnp.float32(i + 1), jnp.float32(5e-4),
                          toks, mask, blp, blp, rewards)
    lp_new, _ = model.seq_logprob(CFG, f, toks, mask)
    lp_old, _ = model.seq_logprob(CFG, flat, toks, mask)
    delta = np.asarray(lp_new - lp_old)
    assert delta[rewards > 0].mean() > delta[rewards < 0].mean()


def test_gae_gamma1_lambda1_is_reward_to_go_minus_value():
    """With gamma = lam = 1 and full mask, GAE telescopes to
    sum_{t'>=t} r_{t'} - V_t."""
    B, T = 2, 6
    rng = np.random.default_rng(0)
    rewards = jnp.asarray(rng.normal(0, 1, (B, T)), jnp.float32)
    values = jnp.asarray(rng.normal(0, 1, (B, T)), jnp.float32)
    mask = jnp.ones((B, T), jnp.float32)
    adv = losses._gae(rewards, values, mask, 1.0, 1.0)
    rtg = jnp.cumsum(rewards[:, ::-1], axis=1)[:, ::-1]
    np.testing.assert_allclose(adv, rtg - values, atol=1e-5, rtol=1e-4)


# --- Reward model ------------------------------------------------------

def test_rm_training_learns_separation(flat):
    toks_c, toks_r = _toks(50, Bp), _toks(51, Bp)
    mask = jnp.ones((Bp, S), jnp.float32)
    step = optim.make_train_step(CFG, losses.reward_model)
    f, m, v = flat, jnp.zeros_like(flat), jnp.zeros_like(flat)
    for i in range(10):
        f, m, v, met = step(f, m, v, jnp.float32(i + 1), jnp.float32(1e-3),
                            toks_c, mask, toks_r, mask)
    assert float(met[1]) == 1.0  # accuracy
    assert float(met[2]) > 0.0  # margin


# --- Adam ---------------------------------------------------------------

def test_adam_matches_reference_implementation():
    rng = np.random.default_rng(0)
    n = 64
    flat = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    grads = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    m = jnp.asarray(np.abs(rng.normal(0, 0.1, n)), jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(0, 0.1, n)), jnp.float32)
    b1, b2, eps, lr, step = 0.9, 0.95, 1e-8, 3e-4, 7.0
    f2, m2, v2, gnorm = optim.adam_update(
        grads, flat, m, v, step, lr, b1, b2, eps, max_grad_norm=1e9
    )
    # hand-rolled reference
    me = b1 * np.asarray(m) + (1 - b1) * np.asarray(grads)
    ve = b2 * np.asarray(v) + (1 - b2) * np.asarray(grads) ** 2
    mh = me / (1 - b1 ** step)
    vh = ve / (1 - b2 ** step)
    fe = np.asarray(flat) - lr * mh / (np.sqrt(vh) + eps)
    np.testing.assert_allclose(f2, fe, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(m2, me, rtol=1e-6)
    np.testing.assert_allclose(v2, ve, rtol=1e-6)
    np.testing.assert_allclose(
        float(gnorm), float(np.linalg.norm(np.asarray(grads))), rtol=1e-5
    )


def test_adam_grad_clipping():
    n = 16
    grads = jnp.full((n,), 100.0)
    flat = jnp.zeros(n)
    m = jnp.zeros(n)
    v = jnp.zeros(n)
    _, m2, _, gnorm = optim.adam_update(
        grads, flat, m, v, 1.0, 1e-3, 0.9, 0.95, 1e-8, max_grad_norm=1.0
    )
    clipped = np.asarray(m2) / 0.1  # m = (1-b1) * g_clipped
    assert np.linalg.norm(clipped) <= 1.01
