"""L1 correctness: Pallas flash attention vs the pure-jnp oracle.

Hypothesis sweeps shapes (including ragged, non-block-multiple sequence
lengths), dtypes, block sizes and causal/non-causal; every case asserts
allclose for the forward, the lse residual, and all three input gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _check(B, H, S, D, bq, bk, causal, dtype, tol):
    keys = jax.random.split(jax.random.PRNGKey(B * 1000 + S * 10 + D), 3)
    q, k, v = (_rand(kk, (B, H, S, D), dtype) for kk in keys)

    out = A.flash_attention(q, k, v, causal, None, bq, bk)
    expect = ref.attention(q, k, v, causal)
    np.testing.assert_allclose(out, expect, atol=tol, rtol=tol)

    lse = A.attention_lse(q, k, v, causal, None, bq, bk)
    np.testing.assert_allclose(
        lse, ref.attention_lse(q, k, v, causal), atol=tol, rtol=tol
    )

    def loss_k(q, k, v):
        return (A.flash_attention(q, k, v, causal, None, bq, bk)
                .astype(jnp.float32) ** 2).sum()

    def loss_r(q, k, v):
        return (ref.attention(q, k, v, causal).astype(jnp.float32) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    scale = max(1.0, float(jnp.max(jnp.abs(jnp.stack([g.astype(jnp.float32).max() for g in gr])))))
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=tol * 10 * scale, rtol=tol * 10)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "B,H,S,D,bq,bk",
    [
        (2, 2, 16, 32, 16, 16),   # exact block multiple
        (2, 2, 24, 32, 16, 16),   # ragged q and k tails
        (1, 1, 7, 8, 4, 4),       # tiny ragged
        (2, 3, 33, 16, 16, 8),    # asymmetric blocks
        (1, 2, 5, 4, 16, 16),     # seq smaller than block
        (4, 2, 48, 32, 16, 16),   # tldr config shape
    ],
)
def test_flash_attention_matches_ref(B, H, S, D, bq, bk, causal):
    _check(B, H, S, D, bq, bk, causal, jnp.float32, 1e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_bf16(causal):
    _check(2, 2, 24, 32, 16, 16, causal, jnp.bfloat16, 3e-2)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    s=st.integers(2, 40),
    d=st.sampled_from([4, 8, 16, 32]),
    bq=st.sampled_from([4, 8, 16]),
    bk=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
)
def test_flash_attention_hypothesis(b, h, s, d, bq, bk, causal):
    _check(b, h, s, d, bq, bk, causal, jnp.float32, 1e-5)


def test_attention_rows_are_convex_combinations():
    """Property: each output row lies in the convex hull of V rows —
    softmax weights are >= 0 and sum to 1, so min(V) <= out <= max(V)
    per feature dimension (over the causal prefix)."""
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    B, H, S, D = 2, 2, 24, 16
    q, k, v = (jax.random.normal(kk, (B, H, S, D)) for kk in keys)
    out = np.asarray(A.flash_attention(q, k, v, True))
    v = np.asarray(v)
    for t in range(S):
        lo = v[:, :, : t + 1].min(axis=2) - 1e-5
        hi = v[:, :, : t + 1].max(axis=2) + 1e-5
        assert (out[:, :, t] >= lo).all() and (out[:, :, t] <= hi).all()


def test_causal_first_row_is_v0():
    """Causally, position 0 attends only to itself: out[0] == v[0]."""
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (jax.random.normal(kk, (1, 1, 9, 8)) for kk in keys)
    out = A.flash_attention(q, k, v, True)
    np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], atol=1e-6)


def test_scale_override():
    keys = jax.random.split(jax.random.PRNGKey(11), 3)
    q, k, v = (jax.random.normal(kk, (1, 2, 12, 8)) for kk in keys)
    out = A.flash_attention(q, k, v, True, 0.25)
    expect = ref.attention(q, k, v, True, 0.25)
    np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-5)
