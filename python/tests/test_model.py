"""L2 model tests: shapes, decode/full-forward equivalence, logprobs, RM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

CFG = configs.CONFIGS["dev"]


@pytest.fixture(scope="module")
def flat():
    return jnp.asarray(model.init_params(CFG, 42)) * 5.0


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(
        rng.integers(1, CFG.vocab, (CFG.gen_batch, CFG.seq_len)), jnp.int32
    )


def test_param_count_matches_layout():
    specs = configs.param_layout(CFG)
    total = sum(s.numel for s in specs)
    assert total == configs.param_count(CFG)
    # offsets are contiguous
    off = 0
    for s in specs:
        assert s.offset == off
        off += s.numel


def test_init_params_deterministic():
    a = model.init_params(CFG, 7)
    b = model.init_params(CFG, 7)
    c = model.init_params(CFG, 8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.shape == (configs.param_count(CFG),)
    assert np.isfinite(a).all()


def test_logits_shape(flat, tokens):
    logits = model.logits_fn(CFG, flat, tokens)
    assert logits.shape == (CFG.gen_batch, CFG.seq_len, CFG.vocab)
    assert jnp.isfinite(logits).all()


def test_ref_and_pallas_paths_agree(flat, tokens):
    logits_pallas = model.logits_fn(CFG, flat, tokens)
    old = model.USE_REF_ATTENTION
    model.USE_REF_ATTENTION = True
    try:
        logits_ref = model.logits_fn(CFG, flat, tokens)
    finally:
        model.USE_REF_ATTENTION = old
    np.testing.assert_allclose(logits_pallas, logits_ref, atol=2e-4, rtol=1e-4)


def test_decode_matches_full_forward(flat, tokens):
    """The incremental KV-cache decode must reproduce full-forward logits."""
    P, S = CFG.prompt_len, CFG.seq_len
    full = model.logits_fn(CFG, flat, tokens)
    kv, lg = model.prefill(CFG, flat, tokens[:, :P])
    np.testing.assert_allclose(lg, full[:, P - 1], atol=1e-4, rtol=1e-4)
    for pos in range(P, S):
        lg, kv = model.decode_step(CFG, flat, kv, tokens[:, pos], pos)
        np.testing.assert_allclose(lg, full[:, pos], atol=1e-4, rtol=1e-4)


def test_token_logprobs_are_logprobs(flat, tokens):
    lp = model.token_logprobs(CFG, flat, tokens)
    assert lp.shape == tokens.shape
    assert (lp <= 1e-6).all()
    assert (lp[:, 0] == 0).all()  # position 0 is unconditioned


def test_seq_logprob_respects_mask(flat, tokens):
    mask = jnp.zeros(tokens.shape, jnp.float32)
    total, _ = model.seq_logprob(CFG, flat, tokens, mask)
    np.testing.assert_allclose(total, 0.0)
    mask_all = jnp.ones(tokens.shape, jnp.float32)
    total_all, tok_lp = model.seq_logprob(CFG, flat, tokens, mask_all)
    np.testing.assert_allclose(total_all, tok_lp.sum(axis=1), rtol=1e-6)


def test_rm_score_reads_last_valid_token(flat, tokens):
    """Truncating the mask must change which position is scored."""
    mask_full = jnp.ones(tokens.shape, jnp.float32)
    mask_short = mask_full.at[:, CFG.seq_len // 2:].set(0.0)
    s_full = model.rm_score(CFG, flat, tokens, mask_full)
    s_short = model.rm_score(CFG, flat, tokens, mask_short)
    assert s_full.shape == (CFG.gen_batch,)
    assert not np.allclose(s_full, s_short)
    # And the short score equals the full score of a truncated batch where
    # trailing tokens are PAD (they are masked out of attention? no — they
    # are *behind* the scored position causally, so only positions after
    # matter: causal attention means tokens after the scored index cannot
    # affect it).
    toks_trunc = tokens.at[:, CFG.seq_len // 2:].set(0)
    s_trunc = model.rm_score(CFG, flat, toks_trunc, mask_short)
    np.testing.assert_allclose(s_short, s_trunc, atol=1e-5, rtol=1e-5)


def test_kv_cache_shape_manifest():
    shape = model.kv_cache_shape(CFG, CFG.gen_batch)
    d = CFG.dims
    assert shape == (d.n_layers, 2, CFG.gen_batch, d.n_heads,
                     CFG.seq_len, d.head_dim)


def test_unpack_roundtrip(flat):
    p = model.unpack(CFG, flat)
    specs = configs.param_layout(CFG)
    assert set(p) == {s.name for s in specs}
    for s in specs:
        assert p[s.name].shape == s.shape
    # concatenating unpacked views reproduces the flat vector
    rebuilt = jnp.concatenate([p[s.name].ravel() for s in specs])
    np.testing.assert_array_equal(rebuilt, flat)


# --- fused generation (model.generate) --------------------------------------

def test_generate_shapes_and_conventions(flat):
    import jax
    import jax.numpy as jnp
    from compile.configs import EOS, PAD

    B, P, S = CFG.gen_batch, CFG.prompt_len, CFG.seq_len
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(4, CFG.vocab, (B, P)), jnp.int32)
    toks, mask, blp = jax.jit(
        lambda f, p, s, t: model.generate(CFG, f, p, s, t)
    )(flat, prompt, 11, jnp.float32(0.7))
    assert toks.shape == (B, S) and mask.shape == (B, S)
    # prompt preserved, mask zero there
    np.testing.assert_array_equal(np.asarray(toks[:, :P]), np.asarray(prompt))
    assert (np.asarray(mask[:, :P]) == 0).all()
    # rows freeze to PAD after EOS
    t = np.asarray(toks)
    m = np.asarray(mask)
    for i in range(B):
        eos_pos = np.where((t[i] == EOS) & (m[i] == 1.0))[0]
        if len(eos_pos):
            after = slice(eos_pos[0] + 1, S)
            assert (t[i, after] == PAD).all()
            assert (m[i, after] == 0).all()


def test_generate_blp_matches_token_logprobs(flat):
    import jax
    import jax.numpy as jnp

    B, P = CFG.gen_batch, CFG.prompt_len
    rng = np.random.default_rng(4)
    prompt = jnp.asarray(rng.integers(4, CFG.vocab, (B, P)), jnp.int32)
    toks, mask, blp = jax.jit(
        lambda f, p, s, t: model.generate(CFG, f, p, s, t)
    )(flat, prompt, 7, jnp.float32(0.7))
    lp = model.token_logprobs(CFG, flat, toks)
    np.testing.assert_allclose(
        np.asarray(lp * mask), np.asarray(blp * mask), atol=2e-4, rtol=1e-3
    )


def test_generate_greedy_is_seed_independent(flat):
    import jax
    import jax.numpy as jnp

    B, P = CFG.gen_batch, CFG.prompt_len
    rng = np.random.default_rng(5)
    prompt = jnp.asarray(rng.integers(4, CFG.vocab, (B, P)), jnp.int32)
    g = jax.jit(lambda f, p, s, t: model.generate(CFG, f, p, s, t))
    t1, _, _ = g(flat, prompt, 1, jnp.float32(-1.0))
    t2, _, _ = g(flat, prompt, 999, jnp.float32(-1.0))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_generate_seeds_differ_when_sampling(flat):
    import jax
    import jax.numpy as jnp

    B, P = CFG.gen_batch, CFG.prompt_len
    rng = np.random.default_rng(6)
    prompt = jnp.asarray(rng.integers(4, CFG.vocab, (B, P)), jnp.int32)
    g = jax.jit(lambda f, p, s, t: model.generate(CFG, f, p, s, t))
    t1, _, _ = g(flat, prompt, 1, jnp.float32(1.0))
    t2, _, _ = g(flat, prompt, 2, jnp.float32(1.0))
    assert not np.array_equal(np.asarray(t1), np.asarray(t2))
