"""Model/task configurations shared by the AOT pipeline and the Rust runtime.

Each `Config` fully determines the artifact set for one (model size, task)
pair: transformer dimensions, sequence geometry, batch sizes, and the
hyperparameters baked into the train-step executables. The manifest written
by `aot.py` mirrors these fields so the Rust coordinator never guesses.

Scale mapping (paper -> this repo, see DESIGN.md §3): Pythia 410m/1B/2.8B
become `s`/`m`/`l`; the controlled-TLDR, GSM8k and No-Robots-chat tasks
become synthetic token tasks with the same reward structure.
"""

from dataclasses import dataclass, field, asdict

# Shared symbolic vocabulary (see rust/src/tokenizer). Key ids the tasks and
# gold rewards rely on; the full table lives on the Rust side.
VOCAB_SIZE = 64
PAD, BOS, EOS, SEP = 0, 1, 2, 3


@dataclass(frozen=True)
class ModelDims:
    """Transformer dimensions. head_dim = d_model // n_heads must be exact."""

    d_model: int
    n_layers: int
    n_heads: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


# Paper scales: Pythia 410m / 1B / 2.8B -> s / m / l. head_dim is kept at 32
# everywhere (an MXU-friendly multiple; see DESIGN.md §4).
SIZES = {
    "xs": ModelDims(d_model=32, n_layers=1, n_heads=2),
    "s": ModelDims(d_model=64, n_layers=2, n_heads=2),
    "m": ModelDims(d_model=128, n_layers=3, n_heads=4),
    "l": ModelDims(d_model=192, n_layers=4, n_heads=6),
}


@dataclass(frozen=True)
class Config:
    """One artifact bundle: a model size bound to a task's sequence geometry.

    - `prompt_len` is exact (synthetic tasks emit fixed-length prompts, no
      left-padding; see DESIGN.md §7).
    - `resp_len` is the maximum generated length; shorter responses are
      EOS-terminated and PAD-filled with a loss mask.
    - `gen_batch` is the generation engine's fixed batch (2 completions per
      prompt for pairwise losses -> gen_batch = 2 * train_pairs).
    """

    name: str
    size: str
    task: str
    prompt_len: int
    resp_len: int
    gen_batch: int
    train_pairs: int  # pairwise minibatch (DPO/RLOO); PPO uses 2*train_pairs singles
    # Hyperparameters baked into executables (paper Tables 4, 7, 10).
    beta_kl: float = 0.05  # KL penalty (PPO/RLOO shaping)
    dpo_beta: float = 0.1  # DPO beta (paper Table 4: Online DPO beta=0.1)
    ppo_clip: float = 0.2
    gae_lambda: float = 0.95
    gae_gamma: float = 1.0
    vf_coef: float = 0.1
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    # Learning rate is a runtime scalar input (fig8 halves it), not baked.

    @property
    def dims(self) -> ModelDims:
        return SIZES[self.size]

    @property
    def seq_len(self) -> int:
        return self.prompt_len + self.resp_len

    @property
    def vocab(self) -> int:
        return VOCAB_SIZE

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            d_model=self.dims.d_model,
            n_layers=self.dims.n_layers,
            n_heads=self.dims.n_heads,
            head_dim=self.dims.head_dim,
            d_ff=self.dims.d_ff,
            seq_len=self.seq_len,
            vocab=self.vocab,
        )
        return d


def _tldr(size: str, **kw) -> Config:
    return Config(
        name=f"tldr_{size}", size=size, task="tldr",
        prompt_len=32, resp_len=16, gen_batch=32, train_pairs=16, **kw,
    )


CONFIGS = {
    # Controlled TLDR setup (paper §3): three policy scales.
    "tldr_s": _tldr("s"),
    "tldr_m": _tldr("m"),
    "tldr_l": _tldr("l"),
    # GSM8k analogue (paper §5.2): exact-match arithmetic, generation-heavy.
    "math_s": Config(
        name="math_s", size="s", task="math",
        prompt_len=16, resp_len=12, gen_batch=32, train_pairs=16,
    ),
    # No-Robots chatbot analogue (paper §5.1), beta from Table 7.
    "chat_m": Config(
        name="chat_m", size="m", task="chat",
        prompt_len=24, resp_len=20, gen_batch=16, train_pairs=8,
        beta_kl=0.03, dpo_beta=0.03,
    ),
    # Tiny config for tests and CI.
    "dev": Config(
        name="dev", size="xs", task="tldr",
        prompt_len=8, resp_len=8, gen_batch=8, train_pairs=4,
    ),
}


# ---------------------------------------------------------------------------
# Flat parameter layout.
#
# All executables operate on a single flat f32 vector; slices are reshaped
# inside the jitted function. The layout below is the single source of truth
# (the manifest exports it for Rust-side debugging/checkpointing).
# ---------------------------------------------------------------------------

@dataclass
class ParamSpec:
    name: str
    shape: tuple
    offset: int

    @property
    def numel(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def param_layout(cfg: Config) -> list:
    """Ordered list of ParamSpec for a policy/RM model (shared layout).

    The value head doubles as the reward-model scalar head; the LM head is
    unused by the RM but kept so both share one layout (DESIGN.md §7).
    """
    dims = cfg.dims
    D, F, V, S = dims.d_model, dims.d_ff, cfg.vocab, cfg.seq_len
    specs, off = [], 0

    def add(name, shape):
        nonlocal off
        spec = ParamSpec(name, tuple(shape), off)
        specs.append(spec)
        off += spec.numel

    add("tok_emb", (V, D))
    add("pos_emb", (S, D))
    for i in range(dims.n_layers):
        add(f"l{i}.ln1", (D,))
        add(f"l{i}.wqkv", (D, 3 * D))
        add(f"l{i}.wo", (D, D))
        add(f"l{i}.ln2", (D,))
        add(f"l{i}.wi", (D, F))
        add(f"l{i}.wo_mlp", (F, D))
    add("final_ln", (D,))
    add("lm_head", (D, V))
    add("value_w", (D,))
    add("value_b", (1,))
    return specs


def param_count(cfg: Config) -> int:
    specs = param_layout(cfg)
    last = specs[-1]
    return last.offset + last.numel
