"""L2: Adam on the flat parameter vector, fused into single train-step HLOs.

`make_train_step(cfg, loss_fn)` returns a function

    (flat, m, v, step, lr, *batch) -> (flat', m', v', metrics)

where `step` (f32 scalar, 1-based) drives bias correction and `lr` is a
runtime input (fig8 halves it). XLA fuses grad + Adam into one executable,
so one Rust `execute` call performs a whole optimizer update.
"""

import jax
import jax.numpy as jnp


def adam_update(grads, flat, m, v, step, lr, b1, b2, eps, max_grad_norm=1.0):
    """One Adam step with global-norm gradient clipping on the flat vector."""
    gnorm = jnp.sqrt(jnp.sum(jnp.square(grads)))
    scale = jnp.minimum(1.0, max_grad_norm / (gnorm + 1e-12))
    grads = grads * scale
    m_new = b1 * m + (1.0 - b1) * grads
    v_new = b2 * v + (1.0 - b2) * jnp.square(grads)
    m_hat = m_new / (1.0 - jnp.power(b1, step))
    v_hat = v_new / (1.0 - jnp.power(b2, step))
    flat_new = flat - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return flat_new, m_new, v_new, gnorm


def make_train_step(cfg, loss_fn, static_kwargs=None):
    """Build the fused train-step callable for one loss function.

    `loss_fn(cfg, flat, *batch, **static_kwargs) -> (loss, metrics)`.
    Hyperparameters in `static_kwargs` (beta, clip, ...) are baked into the
    HLO; `lr` stays a runtime input. The last metrics slot is overwritten
    with the clipped-gradient norm.
    """
    static_kwargs = static_kwargs or {}

    def train_step(flat, m, v, step, lr, *batch):
        def lf(p):
            return loss_fn(cfg, p, *batch, **static_kwargs)

        (_, metrics), grads = jax.value_and_grad(lf, has_aux=True)(flat)
        flat_new, m_new, v_new, gnorm = adam_update(
            grads, flat, m, v, step, lr,
            cfg.adam_b1, cfg.adam_b2, cfg.adam_eps,
        )
        metrics = metrics.at[-1].set(gnorm)
        return flat_new, m_new, v_new, metrics

    return train_step
