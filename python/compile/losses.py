"""L2: the RLHF loss zoo (paper §2.1, §3.3, Appendix B).

Every loss is a function `(cfg, flat_params, *batch) -> (scalar loss,
metrics [NUM_METRICS])` differentiated and wrapped into a fused
Adam train-step executable by `optim.make_train_step`.

Conventions shared with the Rust coordinator:
- `tokens` are full sequences [B, S] = prompt ++ response ++ PAD.
- `mask` is 1.0 exactly on response positions that should be scored
  (response tokens up to and including EOS).
- `blp*` are *behaviour* logprobs — token logprobs under the policy that
  generated the data (accumulated by the generation engine). On-policy,
  blp == current logprobs; off-policy they differ, which is exactly the
  paper's subject of study.
- `rlp*` are logprobs under the frozen reference/SFT policy (KL anchor).
- Rewards `r*` are raw task/RM rewards [B]; the KL penalty is applied
  inside the loss from blp/rlp so every method sees the same objective
  `r - beta * KL` (paper eq. 1).

Metrics layout is fixed-width so the Rust side reads a uniform f32 vector;
`metric_names(loss)` in aot.py documents each slot in the manifest.
"""

import jax
import jax.numpy as jnp

from . import model

NUM_METRICS = 8


def _pad_metrics(*ms):
    v = jnp.stack([jnp.asarray(m, jnp.float32) for m in ms])
    return jnp.pad(v, (0, NUM_METRICS - v.shape[0]))


def _masked_mean(x, mask):
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Supervised fine-tuning (also Best-of-N's update rule, paper §3.3)
# ---------------------------------------------------------------------------

def sft(cfg, flat, tokens, mask):
    """Masked next-token cross-entropy over response positions."""
    lp = model.token_logprobs(cfg, flat, tokens)
    nll = -_masked_mean(lp, mask)
    ppl = jnp.exp(nll)
    return nll, _pad_metrics(nll, ppl, jnp.sum(mask))


# ---------------------------------------------------------------------------
# Reward model: Bradley-Terry pairwise loss (paper §2.1)
# ---------------------------------------------------------------------------

def reward_model(cfg, flat, tok_c, mask_c, tok_r, mask_r):
    """-log sigmoid(score(chosen) - score(rejected)).

    Masks here cover the *whole* valid sequence (prompt + response) because
    the score is read at the last valid token.
    """
    s_c = model.rm_score(cfg, flat, tok_c, mask_c)
    s_r = model.rm_score(cfg, flat, tok_r, mask_r)
    margin = s_c - s_r
    loss = -jnp.mean(jax.nn.log_sigmoid(margin))
    acc = jnp.mean((margin > 0).astype(jnp.float32))
    return loss, _pad_metrics(loss, acc, jnp.mean(margin),
                              jnp.mean(s_c), jnp.mean(s_r))


# ---------------------------------------------------------------------------
# Online DPO (Guo et al. 2024; the paper's most off-policy-robust method)
# ---------------------------------------------------------------------------

def online_dpo(cfg, flat, tok_pos, mask_pos, tok_neg, mask_neg,
               rlp_pos, rlp_neg, beta):
    """DPO objective on online pairs ranked by the reward model.

    rlp_pos/rlp_neg: [B] sequence logprobs under the *reference* (SFT init)
    policy, computed by the Rust side with the logprob executable.
    """
    lp_pos, _ = model.seq_logprob(cfg, flat, tok_pos, mask_pos)
    lp_neg, _ = model.seq_logprob(cfg, flat, tok_neg, mask_neg)
    margin = beta * ((lp_pos - rlp_pos) - (lp_neg - rlp_neg))
    loss = -jnp.mean(jax.nn.log_sigmoid(margin))
    acc = jnp.mean((margin > 0).astype(jnp.float32))
    return loss, _pad_metrics(
        loss, acc, jnp.mean(margin),
        jnp.mean(lp_pos), jnp.mean(lp_neg),
        jnp.mean(lp_pos - rlp_pos), jnp.mean(lp_neg - rlp_neg),
    )


# ---------------------------------------------------------------------------
# PPO (Schulman et al. 2017; TRL/N+-implementation-details style)
# ---------------------------------------------------------------------------

def _gae(rewards, values, mask, gamma, lam):
    """Masked GAE over the time axis. rewards/values/mask: [B, S]."""
    s = rewards.shape[1]

    def step(carry, t):
        gae = carry
        v_next = jnp.where(t + 1 < s, values[:, (t + 1) % s] * mask[:, (t + 1) % s], 0.0)
        delta = rewards[:, t] + gamma * v_next - values[:, t]
        gae = delta + gamma * lam * gae
        gae = gae * mask[:, t]
        return gae, gae

    ts = jnp.arange(s - 1, -1, -1)
    _, adv_rev = jax.lax.scan(step, jnp.zeros(rewards.shape[0]), ts)
    return adv_rev[::-1].T  # [B, S]


def ppo(cfg, flat, tokens, mask, blp, rlp, rewards,
        beta, clip, gamma, lam, vf_coef):
    """Clipped-surrogate PPO with a value head and token-level KL penalty.

    tokens/mask/blp/rlp: [B, S]; rewards: [B] applied at the last response
    token. Per-token reward r_t = -beta * (blp_t - rlp_t) + [t == last] * R,
    the standard RLHF shaping (Ziegler et al. 2019).
    """
    logits, values = model.logits_and_values(cfg, flat, tokens)
    logp_all = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    lp = jnp.take_along_axis(logp_all, tokens[:, 1:, None], axis=-1)[..., 0]
    lp = jnp.pad(lp, ((0, 0), (1, 0)))  # [B, S]

    # Token-level shaped rewards on response positions.
    kl_pen = -beta * (blp - rlp) * mask
    last = jnp.maximum(jnp.sum(mask, axis=1) - 1.0, 0.0)
    pos = jnp.arange(tokens.shape[1])[None, :].astype(jnp.float32)
    # Response positions start after the prompt; `mask` encodes them, and
    # the terminal reward lands on the last masked position.
    prompt_offset = jnp.argmax(mask, axis=1).astype(jnp.float32)
    is_last = (pos == (prompt_offset + last)[:, None]).astype(jnp.float32) * mask
    tok_rewards = kl_pen + is_last * rewards[:, None]

    adv = _gae(tok_rewards, values * mask, mask, gamma, lam)
    returns = adv + values * mask
    # Masked advantage whitening.
    mean = _masked_mean(adv, mask)
    var = _masked_mean(jnp.square(adv - mean), mask)
    adv_w = (adv - mean) * jax.lax.rsqrt(var + 1e-8)

    ratio = jnp.exp(jnp.clip(lp - blp, -20.0, 20.0))
    pg1 = -adv_w * ratio
    pg2 = -adv_w * jnp.clip(ratio, 1.0 - clip, 1.0 + clip)
    pg_loss = _masked_mean(jnp.maximum(pg1, pg2), mask)
    v_loss = 0.5 * _masked_mean(jnp.square(values - returns), mask)
    loss = pg_loss + vf_coef * v_loss

    approx_kl = _masked_mean(blp - lp, mask)
    clipfrac = _masked_mean(
        (jnp.abs(ratio - 1.0) > clip).astype(jnp.float32), mask
    )
    probs = jax.nn.softmax(logits, axis=-1)
    ent_tok = -jnp.sum(
        probs * jax.nn.log_softmax(logits, axis=-1), axis=-1
    )
    entropy = _masked_mean(ent_tok, mask)
    return loss, _pad_metrics(
        loss, pg_loss, v_loss, approx_kl, clipfrac, entropy,
        _masked_mean(ratio, mask), jnp.mean(rewards),
    )


# ---------------------------------------------------------------------------
# RLOO family (Ahmadian et al. 2024; paper Appendix B)
# ---------------------------------------------------------------------------

def _rloo_adv(r1, r2, blp1, blp2, rlp1, rlp2, beta):
    """KL-shaped two-sample leave-one-out advantages (antisymmetric)."""
    sum1 = jnp.sum(blp1, axis=1)
    sum2 = jnp.sum(blp2, axis=1)
    ref1 = jnp.sum(rlp1, axis=1)
    ref2 = jnp.sum(rlp2, axis=1)
    rt1 = r1 - beta * (sum1 - ref1)
    rt2 = r2 - beta * (sum2 - ref2)
    a1 = rt1 - rt2
    return a1, -a1


def rloo(cfg, flat, tok1, mask1, tok2, mask2, blp1, blp2, rlp1, rlp2,
         r1, r2, beta):
    """Vanilla RLOO (k=2): REINFORCE with the other sample as baseline."""
    lp1, _ = model.seq_logprob(cfg, flat, tok1, mask1)
    lp2, _ = model.seq_logprob(cfg, flat, tok2, mask2)
    a1, a2 = _rloo_adv(r1, r2, blp1 * mask1, blp2 * mask2,
                       rlp1 * mask1, rlp2 * mask2, beta)
    loss = -jnp.mean(lp1 * a1 + lp2 * a2) / 2.0
    return loss, _pad_metrics(
        loss, jnp.mean(jnp.abs(a1)), jnp.mean(lp1), jnp.mean(lp2),
        jnp.mean(r1), jnp.mean(r2),
    )


def proximal_rloo(cfg, flat, tok1, mask1, tok2, mask2, blp1, blp2,
                  rlp1, rlp2, r1, r2, beta, clip):
    """Paper Appendix B: RLOO with a clipped sequence-level IS ratio.

    ratio_i = exp(logpi_theta(y_i) - logpi_behaviour(y_i)), clipped to
    [1-eps, 1+eps] PPO-style; this is what makes RLOO usable off-policy
    (Fig 13: CoPG collapses at N=16, Proximal RLOO survives).
    """
    lp1, _ = model.seq_logprob(cfg, flat, tok1, mask1)
    lp2, _ = model.seq_logprob(cfg, flat, tok2, mask2)
    b1 = jnp.sum(blp1 * mask1, axis=1)
    b2 = jnp.sum(blp2 * mask2, axis=1)
    a1, a2 = _rloo_adv(r1, r2, blp1 * mask1, blp2 * mask2,
                       rlp1 * mask1, rlp2 * mask2, beta)

    def clipped_term(lp, blp_sum, adv):
        ratio = jnp.exp(jnp.clip(lp - blp_sum, -20.0, 20.0))
        unclipped = ratio * adv
        clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
        return jnp.minimum(unclipped, clipped), ratio

    t1, ratio1 = clipped_term(lp1, b1, a1)
    t2, ratio2 = clipped_term(lp2, b2, a2)
    loss = -jnp.mean(t1 + t2) / 2.0
    clipfrac = jnp.mean(
        (jnp.abs(jnp.concatenate([ratio1, ratio2]) - 1.0) > clip)
        .astype(jnp.float32)
    )
    return loss, _pad_metrics(
        loss, jnp.mean(jnp.abs(a1)), jnp.mean(ratio1), jnp.mean(ratio2),
        clipfrac, jnp.mean(r1), jnp.mean(r2),
    )


# ---------------------------------------------------------------------------
# Device-side batch assembly (not a loss): best/worst pair gather
# ---------------------------------------------------------------------------

def gather_pairs(cfg, tok_a, mask_a, blp_a, rlp_a, rseq_a,
                 tok_b, mask_b, blp_b, rlp_b, rseq_b, idx):
    """Permute round-layout buffers into best/worst train-batch layout.

    The Rust coordinator keeps a round's [Bg, S] token/mask/blp/rlp tensors
    (and the [Bg] reference sequence logprobs) device-resident; only the
    [2*Bp] pair-index vector — best-side rows then worst-side rows,
    computed on host from the rewards — is uploaded per train batch. Two
    round inputs cover the K=4 two-round ladder (rows of round b are
    addressed at Bg + i); the K=2 single-round case passes the same
    buffers for a and b with indices < Bg.

    Outputs (train-batch layout, stay device-resident):
      0..3   tok1/mask1/tok2/mask2          [Bp, S]   DPO + RLOO family
      4..7   blp1/blp2/rlp1/rlp2            [Bp, S]   RLOO family
      8..9   rseq1/rseq2                    [Bp]      DPO reference margins
      10..11 tok_all/mask_all (rows = idx)  [2*Bp, S] Best-of-N singles
    """
    bp = cfg.train_pairs
    tok = jnp.concatenate([tok_a, tok_b], axis=0)
    mask = jnp.concatenate([mask_a, mask_b], axis=0)
    blp = jnp.concatenate([blp_a, blp_b], axis=0)
    rlp = jnp.concatenate([rlp_a, rlp_b], axis=0)
    rseq = jnp.concatenate([rseq_a, rseq_b], axis=0)
    i1, i2 = idx[:bp], idx[bp:]
    return (tok[i1], mask[i1], tok[i2], mask[i2],
            blp[i1], blp[i2], rlp[i1], rlp[i2],
            rseq[i1], rseq[i2], tok[idx], mask[idx])


def copg(cfg, flat, tok1, mask1, tok2, mask2, blp1, blp2, rlp1, rlp2,
         r1, r2, beta):
    """CoPG-style RLOO (Flet-Berliac et al. 2024), paper Appendix B.

    loss_i = -log(pi_theta(y_i)/pi_old(y_i)) * A_i. Identical *gradient* to
    vanilla RLOO (the log pi_old term is constant), implemented literally so
    Fig 13 compares the objectives as published.
    """
    lp1, _ = model.seq_logprob(cfg, flat, tok1, mask1)
    lp2, _ = model.seq_logprob(cfg, flat, tok2, mask2)
    b1 = jnp.sum(blp1 * mask1, axis=1)
    b2 = jnp.sum(blp2 * mask2, axis=1)
    a1, a2 = _rloo_adv(r1, r2, blp1 * mask1, blp2 * mask2,
                       rlp1 * mask1, rlp2 * mask2, beta)
    loss = -jnp.mean((lp1 - b1) * a1 + (lp2 - b2) * a2) / 2.0
    return loss, _pad_metrics(
        loss, jnp.mean(jnp.abs(a1)), jnp.mean(lp1 - b1), jnp.mean(lp2 - b2),
        jnp.mean(r1), jnp.mean(r2),
    )
