"""AOT pipeline: lower every executable for a config to HLO text + manifest.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts [--config dev tldr_s ...]

Outputs per config (DESIGN.md §7):
    artifacts/<config>/manifest.json
    artifacts/<config>/init_policy.npy, init_rm.npy
    artifacts/<config>/<name>.hlo.txt for every executable
Plus a top-level artifacts/index.json listing built configs.

`make artifacts` is incremental: a config is skipped when its manifest is
newer than every file in python/compile/.
"""

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, losses, model, optim

F32, I32 = jnp.float32, jnp.int32


def to_hlo_text(lowered, return_tuple=True) -> str:
    """Lower to HLO text. `return_tuple=False` emits *untupled* outputs so
    PJRT hands back one device buffer per output — the generation hot path
    (prefill/decode) uses this to keep the KV cache device-resident and
    fetch only the logits (EXPERIMENTS.md §Perf)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


# Artifacts the runtime executes on the buffer path: the Rust side
# (Engine::execute_buffers) keeps their outputs as device buffers, so hot
# state stays device-resident between calls and only what the host needs
# is downloaded. (For multi-output modules return_tuple does not change
# the emitted HLO — the root stays a tuple — so the flag is a runtime
# protocol marker; clients whose PJRT execute untuples the root get
# per-output buffers for free, and the engine falls back to a host-side
# tuple split on clients that return one tuple buffer.) Concretely:
# train steps keep (params, m, v) on device and fetch just the metrics;
# the fused generate fetches its three sampled outputs with the policy
# served from the device cache. Tupled artifacts (prefill/decode/logprob/
# score_rm) still return one tuple literal via Engine::call — the
# step-wise engines deliberately stay on that path as the Fig-14
# middle tier.
#
# Besides the names below, build_config registers buffer-path TWINS of
# prefill/decode/logprob (`*_dev`): aliases of the SAME emitted HLO files
# re-entered in the manifest with untupled=True (for multi-output modules
# return_tuple does not change the HLO, so the twin IS the same
# computation). The tupled originals stay as the literal baselines:
# `prefill`/`decode` for the Fig-14 middle-tier CachedEngine, while the
# twins let the DeviceCachedEngine chain the KV cache device-to-device
# and round labelling share one uploaded token/mask pair across
# labelling and training, fetching only the outputs it reads. score_rm
# has a single output, which the untupled protocol cannot represent
# (see the >=2-outputs guard below), so it stays tupled — its *inputs*
# still come from shared device buffers on the resident path.
UNTUPLED = {
    "generate",
    "gather_pairs",
    "train_sft",
    "train_rm",
    "train_dpo",
    "train_ppo",
    "train_rloo",
    "train_prloo",
    "train_copg",
}


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_entry(name, shape, dtype):
    return {"name": name, "shape": list(shape),
            "dtype": "f32" if dtype == F32 else "i32"}


# ---------------------------------------------------------------------------
# Executable definitions
# ---------------------------------------------------------------------------

METRIC_NAMES = {
    "sft": ["loss", "ppl", "tokens", "", "", "", "", "grad_norm"],
    "rm": ["loss", "acc", "margin", "score_chosen", "score_rejected", "",
           "", "grad_norm"],
    "dpo": ["loss", "acc", "margin", "lp_pos", "lp_neg", "klp_pos",
            "klp_neg", "grad_norm"],
    "ppo": ["loss", "pg_loss", "v_loss", "approx_kl", "clipfrac", "entropy",
            "mean_ratio", "grad_norm"],
    "rloo": ["loss", "abs_adv", "lp1", "lp2", "r1", "r2", "", "grad_norm"],
    "prloo": ["loss", "abs_adv", "ratio1", "ratio2", "clipfrac", "r1", "r2",
              "grad_norm"],
    "copg": ["loss", "abs_adv", "lograt1", "lograt2", "r1", "r2", "",
             "grad_norm"],
}


def executable_defs(cfg: configs.Config):
    """(name, fn, [(arg_name, shape, dtype)], metric_key|None) per artifact.

    Bg = generation batch (singles), Bp = pairwise train batch.
    """
    n = configs.param_count(cfg)
    S, P, V = cfg.seq_len, cfg.prompt_len, cfg.vocab
    Bg, Bp = cfg.gen_batch, cfg.train_pairs
    cache = model.kv_cache_shape(cfg, Bg)

    opt_args = [("params", (n,), F32), ("m", (n,), F32), ("v", (n,), F32),
                ("step", (), F32), ("lr", (), F32)]

    def seq(name, b=Bg):
        return [(f"tok{name}", (b, S), I32), (f"mask{name}", (b, S), F32)]

    def rloo_args():
        return (
            opt_args
            + seq("1", Bp) + seq("2", Bp)
            + [("blp1", (Bp, S), F32), ("blp2", (Bp, S), F32),
               ("rlp1", (Bp, S), F32), ("rlp2", (Bp, S), F32),
               ("r1", (Bp,), F32), ("r2", (Bp,), F32)]
        )

    beta, clip = cfg.beta_kl, cfg.ppo_clip

    defs = [
        # --- generation / scoring path ---
        ("prefill",
         lambda flat, tokens: model.prefill(cfg, flat, tokens),
         [("params", (n,), F32), ("tokens", (Bg, P), I32)], None),
        ("decode",
         lambda flat, kv, tok, pos: model.decode_step(cfg, flat, kv, tok, pos),
         [("params", (n,), F32), ("kv", cache, F32),
          ("tok", (Bg,), I32), ("pos", (), I32)], None),
        ("generate",
         lambda flat, prompt, seed, temp: model.generate(
             cfg, flat, prompt, seed, temp),
         [("params", (n,), F32), ("prompt", (Bg, P), I32),
          ("seed", (), I32), ("temperature", (), F32)], None),
        ("forward_full",
         lambda flat, tokens: (model.logits_fn(cfg, flat, tokens),),
         [("params", (n,), F32), ("tokens", (Bg, S), I32)], None),
        # Device-side best/worst pair gather (losses.gather_pairs): turns
        # two rounds' resident [Bg, S] buffers plus a host [2*Bp] index
        # vector into train-batch-layout tensors that never leave the
        # device. Untupled so the runtime chains the outputs straight into
        # the pairwise train_* executables.
        ("gather_pairs",
         lambda *a: losses.gather_pairs(cfg, *a),
         [("tok_a", (Bg, S), I32), ("mask_a", (Bg, S), F32),
          ("blp_a", (Bg, S), F32), ("rlp_a", (Bg, S), F32),
          ("rseq_a", (Bg,), F32),
          ("tok_b", (Bg, S), I32), ("mask_b", (Bg, S), F32),
          ("blp_b", (Bg, S), F32), ("rlp_b", (Bg, S), F32),
          ("rseq_b", (Bg,), F32),
          ("pair_idx", (2 * Bp,), I32)], None),
        ("logprob",
         lambda flat, tokens, mask: model.seq_logprob(cfg, flat, tokens, mask),
         [("params", (n,), F32), ("tokens", (Bg, S), I32),
          ("mask", (Bg, S), F32)], None),
        ("score_rm",
         lambda flat, tokens, mask: (model.rm_score(cfg, flat, tokens, mask),),
         [("params", (n,), F32), ("tokens", (Bg, S), I32),
          ("mask", (Bg, S), F32)], None),
        # --- training path (fused loss+grad+Adam) ---
        ("train_sft", optim.make_train_step(cfg, losses.sft),
         opt_args + seq("", Bg), "sft"),
        ("train_rm", optim.make_train_step(cfg, losses.reward_model),
         opt_args + seq("_c", Bp) + seq("_r", Bp), "rm"),
        ("train_dpo",
         optim.make_train_step(cfg, losses.online_dpo,
                               {"beta": cfg.dpo_beta}),
         opt_args + seq("_pos", Bp) + seq("_neg", Bp)
         + [("rlp_pos", (Bp,), F32), ("rlp_neg", (Bp,), F32)], "dpo"),
        ("train_ppo",
         optim.make_train_step(cfg, losses.ppo, {
             "beta": beta, "clip": clip, "gamma": cfg.gae_gamma,
             "lam": cfg.gae_lambda, "vf_coef": cfg.vf_coef,
         }),
         opt_args + seq("", Bg)
         + [("blp", (Bg, S), F32), ("rlp", (Bg, S), F32),
            ("rewards", (Bg,), F32)], "ppo"),
        ("train_rloo",
         optim.make_train_step(cfg, losses.rloo, {"beta": beta}),
         rloo_args(), "rloo"),
        ("train_prloo",
         optim.make_train_step(cfg, losses.proximal_rloo,
                               {"beta": beta, "clip": clip}),
         rloo_args(), "prloo"),
        ("train_copg",
         optim.make_train_step(cfg, losses.copg, {"beta": beta}),
         rloo_args(), "copg"),
    ]
    return defs


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------

def build_config(cfg: configs.Config, out_dir: str, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    artifacts = {}
    t_start = time.time()
    for name, fn, args, metric_key in executable_defs(cfg):
        t0 = time.time()
        in_specs = [_spec(shape, dtype) for _, shape, dtype in args]
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered, return_tuple=name not in UNTUPLED)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *in_specs)
        outs = [
            _io_entry(f"out{i}", o.shape, o.dtype)
            for i, o in enumerate(jax.tree_util.tree_leaves(out_tree))
        ]
        if name in UNTUPLED and len(outs) < 2:
            # The runtime tells an untupling client's per-leaf result apart
            # from a fallback client's root-tuple buffer by output count —
            # a 1-output untupled artifact would be ambiguous (both look
            # like one buffer). Keep single-output artifacts tupled.
            raise ValueError(
                f"{name}: untupled artifacts need >= 2 outputs, got {len(outs)}"
            )
        artifacts[name] = {
            "file": fname,
            "inputs": [_io_entry(n, s, d) for n, s, d in args],
            "outputs": outs,
            "metrics": METRIC_NAMES.get(metric_key, []),
            "untupled": name in UNTUPLED,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        if verbose:
            print(f"  {cfg.name}/{name}: {len(text) / 1024:.0f} KB "
                  f"({time.time() - t0:.1f}s)")

    # train_bon (Best-of-N SFT, paper §3.3) reuses the SFT executable.
    artifacts["train_bon"] = dict(artifacts["train_sft"])

    # Buffer-path twins: same HLO file as the tupled namesake (for
    # multi-output modules return_tuple does not change the emitted HLO,
    # so the twin IS the same computation — bitwise-identical outputs),
    # re-registered with untupled=True so the runtime executes them via
    # execute_buffers and keeps outputs device-resident. The tupled
    # originals stay in the manifest as the literal-path baseline.
    for twin in ["prefill", "decode", "logprob"]:
        assert len(artifacts[twin]["outputs"]) >= 2, twin
        artifacts[f"{twin}_dev"] = dict(artifacts[twin], untupled=True)

    # Seeded initial parameters. Policy and RM start from the same layout;
    # distinct seeds so the proxy RM is not the policy.
    np.save(os.path.join(out_dir, "init_policy.npy"),
            model.init_params(cfg, seed=1234))
    np.save(os.path.join(out_dir, "init_rm.npy"),
            model.init_params(cfg, seed=5678))

    manifest = {
        "config": cfg.to_dict(),
        "param_count": configs.param_count(cfg),
        "param_layout": [
            {"name": s.name, "shape": list(s.shape), "offset": s.offset}
            for s in configs.param_layout(cfg)
        ],
        "kv_cache_shape": list(model.kv_cache_shape(cfg, cfg.gen_batch)),
        "artifacts": artifacts,
        "built_unix": int(time.time()),
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"  {cfg.name}: done in {time.time() - t_start:.1f}s, "
              f"{configs.param_count(cfg):,} params")
    return manifest


def _sources_mtime() -> float:
    src_dir = os.path.dirname(os.path.abspath(__file__))
    mt = 0.0
    for root, _, files in os.walk(src_dir):
        for f in files:
            if f.endswith(".py"):
                mt = max(mt, os.path.getmtime(os.path.join(root, f)))
    return mt


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", nargs="*", default=sorted(configs.CONFIGS),
                    help="configs to build (default: all)")
    ap.add_argument("--force", action="store_true",
                    help="rebuild even if up to date")
    args = ap.parse_args()

    src_mtime = _sources_mtime()
    built = []
    for name in args.config:
        cfg = configs.CONFIGS[name]
        out_dir = os.path.join(args.out, name)
        mpath = os.path.join(out_dir, "manifest.json")
        if (not args.force and os.path.exists(mpath)
                and os.path.getmtime(mpath) >= src_mtime):
            print(f"  {name}: up to date")
            built.append(name)
            continue
        print(f"building {name} ...")
        build_config(cfg, out_dir)
        built.append(name)

    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump({"configs": built}, f, indent=1)


if __name__ == "__main__":
    main()
