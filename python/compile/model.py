"""L2: the policy/reward-model transformer over a flat parameter vector.

Decoder-only pre-norm transformer (RMSNorm, causal flash attention from the
L1 Pallas kernel, GELU MLP, learned positional embeddings). Every public
function takes the *flat* f32 parameter vector as its first tensor argument
and unpacks slices internally, so the compiled HLO executables present a
single opaque buffer to the Rust runtime (DESIGN.md §7).

Heads:
- LM head  -> next-token logits (policy).
- Value head -> per-token scalar (PPO critic) and, applied at the last
  valid token, the reward-model score (the two roles share a layout so
  policy and RM checkpoints are interchangeable buffers).

Generation path: `prefill` builds the KV cache for the fixed-length prompt
and returns the first sampling distribution; `decode_step` consumes one
token per call against the cache. Both are exported as separate HLO
artifacts driven by the Rust generation engines.
"""

import os

import jax
import jax.numpy as jnp

from . import configs
from .kernels import attention as attn_kernel
from .kernels import ref as attn_ref

# Flip to True (or set USE_REF_ATTENTION=1) to bypass the Pallas kernel
# (debugging aid; tests compare both paths).
USE_REF_ATTENTION = os.environ.get("USE_REF_ATTENTION", "").lower() not in (
    "", "0", "false", "no",
)

RMS_EPS = 1e-5


def _attention(q, k, v):
    if USE_REF_ATTENTION:
        return attn_ref.attention(q, k, v, causal=True)
    return attn_kernel.flash_attention(q, k, v, True)


# ---------------------------------------------------------------------------
# Parameter handling
# ---------------------------------------------------------------------------

def unpack(cfg: configs.Config, flat):
    """Flat f32 vector -> dict of named, shaped arrays (views)."""
    out = {}
    for spec in configs.param_layout(cfg):
        out[spec.name] = jax.lax.dynamic_slice(
            flat, (spec.offset,), (spec.numel,)
        ).reshape(spec.shape)
    return out


def init_params(cfg: configs.Config, seed: int):
    """Seeded initial flat params (written to artifacts as .npy).

    Scaled-normal init: embeddings/attention 0.02, output projections
    scaled down by sqrt(2*n_layers) (GPT-2 style residual scaling), norms 1.
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    n_layers = cfg.dims.n_layers
    chunks = []
    for spec in configs.param_layout(cfg):
        name = spec.name.split(".")[-1]
        if name in ("ln1", "ln2", "final_ln"):
            w = np.ones(spec.numel, dtype=np.float32)
        elif name in ("wo", "wo_mlp"):
            std = 0.02 / np.sqrt(2.0 * n_layers)
            w = rng.normal(0.0, std, spec.numel).astype(np.float32)
        elif name == "value_b":
            w = np.zeros(spec.numel, dtype=np.float32)
        else:
            w = rng.normal(0.0, 0.02, spec.numel).astype(np.float32)
        chunks.append(w)
    return np.concatenate(chunks)


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------

def _rmsnorm(x, scale):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + RMS_EPS) * scale


def _split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _block(cfg, p, i, h, return_kv=False):
    """One pre-norm transformer block over full sequences [B, S, D]."""
    n_heads = cfg.dims.n_heads
    a = _rmsnorm(h, p[f"l{i}.ln1"])
    qkv = a @ p[f"l{i}.wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    qh, kh, vh = (_split_heads(x, n_heads) for x in (q, k, v))
    ctx = _attention(qh, kh, vh)
    h = h + _merge_heads(ctx) @ p[f"l{i}.wo"]
    a = _rmsnorm(h, p[f"l{i}.ln2"])
    h = h + jax.nn.gelu(a @ p[f"l{i}.wi"]) @ p[f"l{i}.wo_mlp"]
    if return_kv:
        return h, (kh, vh)
    return h


def forward_hidden(cfg, flat, tokens, return_kv=False):
    """tokens [B, S'] (S' <= seq_len) -> hidden [B, S', D]."""
    p = unpack(cfg, flat)
    s = tokens.shape[1]
    h = p["tok_emb"][tokens] + p["pos_emb"][:s][None, :, :]
    kvs = []
    for i in range(cfg.dims.n_layers):
        if return_kv:
            h, kv = _block(cfg, p, i, h, return_kv=True)
            kvs.append(kv)
        else:
            h = _block(cfg, p, i, h)
    h = _rmsnorm(h, p["final_ln"])
    if return_kv:
        return h, kvs, p
    return h, p


def logits_fn(cfg, flat, tokens):
    """Full-sequence next-token logits [B, S, V] (naive engine + training)."""
    h, p = forward_hidden(cfg, flat, tokens)
    return h @ p["lm_head"]


def values_fn(cfg, flat, tokens):
    """Per-token value estimates [B, S] (PPO critic)."""
    h, p = forward_hidden(cfg, flat, tokens)
    return h @ p["value_w"] + p["value_b"]


def logits_and_values(cfg, flat, tokens):
    h, p = forward_hidden(cfg, flat, tokens)
    return h @ p["lm_head"], h @ p["value_w"] + p["value_b"]


def rm_score(cfg, flat, tokens, mask):
    """Reward-model score [B]: value head at the last valid token.

    mask [B, S] is 1.0 on valid (non-PAD) positions; the score is read at
    index sum(mask)-1 per row.
    """
    h, p = forward_hidden(cfg, flat, tokens)
    vals = h @ p["value_w"] + p["value_b"]  # [B, S]
    last = jnp.maximum(jnp.sum(mask, axis=1).astype(jnp.int32) - 1, 0)
    return jnp.take_along_axis(vals, last[:, None], axis=1)[:, 0]


def token_logprobs(cfg, flat, tokens):
    """log p(tokens[t] | tokens[<t]) for t >= 1; position 0 gets 0.

    Returns [B, S]. Callers apply their own response masks.
    """
    logits = logits_fn(cfg, flat, tokens)  # [B, S, V]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    lp = jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)[..., 0]
    return jnp.pad(lp, ((0, 0), (1, 0)))


def seq_logprob(cfg, flat, tokens, mask):
    """Masked sequence log-probability [B] plus token logprobs [B, S]."""
    lp = token_logprobs(cfg, flat, tokens)
    return jnp.sum(lp * mask, axis=1), lp


# ---------------------------------------------------------------------------
# Generation path: prefill + single-token decode against a KV cache
# ---------------------------------------------------------------------------
#
# Cache layout: [n_layers, 2, B, H, seq_len, head_dim] f32. The Rust engine
# owns the buffer and threads it through decode_step calls.

def kv_cache_shape(cfg, batch):
    d = cfg.dims
    return (d.n_layers, 2, batch, d.n_heads, cfg.seq_len, d.head_dim)


def prefill(cfg, flat, tokens):
    """tokens [B, P] (fixed-length prompts) -> (kv cache, last logits [B,V])."""
    h, kvs, p = forward_hidden(cfg, flat, tokens, return_kv=True)
    b = tokens.shape[0]
    cache = jnp.zeros(kv_cache_shape(cfg, b), jnp.float32)
    for i, (kh, vh) in enumerate(kvs):
        # kh, vh: [B, H, P, Dh] -> cache[i, 0/1, :, :, :P]
        cache = jax.lax.dynamic_update_slice(
            cache, kh[None, None], (i, 0, 0, 0, 0, 0)
        )
        cache = jax.lax.dynamic_update_slice(
            cache, vh[None, None], (i, 1, 0, 0, 0, 0)
        )
    logits = h[:, -1] @ p["lm_head"]
    return cache, logits


def decode_step(cfg, flat, cache, tok, pos):
    """One incremental decode step.

    cache: [L, 2, B, H, S, Dh]; tok: [B] i32 (token at position `pos`);
    pos: scalar i32. Returns (logits [B, V] for position pos+1, new cache).
    """
    p = unpack(cfg, flat)
    dims = cfg.dims
    n_heads, head_dim = dims.n_heads, dims.head_dim
    b = tok.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(head_dim))

    h = p["tok_emb"][tok] + jax.lax.dynamic_slice(
        p["pos_emb"], (pos, 0), (1, dims.d_model)
    )  # [B, D]
    s_axis = cfg.seq_len
    pos_ids = jax.lax.iota(jnp.int32, s_axis)
    attn_mask = (pos_ids <= pos)[None, None, :]  # [1, 1, S]

    for i in range(dims.n_layers):
        a = _rmsnorm(h, p[f"l{i}.ln1"])
        qkv = a @ p[f"l{i}.wqkv"]  # [B, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        qh = q.reshape(b, n_heads, head_dim)
        kh = k.reshape(b, n_heads, head_dim)
        vh = v.reshape(b, n_heads, head_dim)
        # Write k, v at `pos`: cache[i, 0, :, :, pos, :] = kh
        cache = jax.lax.dynamic_update_slice(
            cache, kh[None, None, :, :, None, :], (i, 0, 0, 0, pos, 0)
        )
        cache = jax.lax.dynamic_update_slice(
            cache, vh[None, None, :, :, None, :], (i, 1, 0, 0, pos, 0)
        )
        keys = cache[i, 0]  # [B, H, S, Dh]
        vals = cache[i, 1]
        scores = jnp.einsum("bhd,bhsd->bhs", qh, keys) * scale
        scores = jnp.where(attn_mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhs,bhsd->bhd", probs, vals).reshape(b, -1)
        h = h + ctx @ p[f"l{i}.wo"]
        a = _rmsnorm(h, p[f"l{i}.ln2"])
        h = h + jax.nn.gelu(a @ p[f"l{i}.wi"]) @ p[f"l{i}.wo_mlp"]

    h = _rmsnorm(h, p["final_ln"])
    return h @ p["lm_head"], cache


# ---------------------------------------------------------------------------
# Fused generation: the whole sampling loop in one executable
# ---------------------------------------------------------------------------

def generate(cfg, flat, prompt, seed, temperature):
    """Prefill + full sampling loop fused into one HLO (EXPERIMENTS.md §Perf).

    The KV cache lives entirely inside the XLA while-loop — zero host
    round-trips per token (the step-wise `decode` path moves the cache
    host<->device every token). One call generates the whole round.

    prompt: [B, P] i32; seed: scalar i32; temperature: scalar f32
    (temperature <= 0 selects greedy argmax decoding).
    Returns (tokens [B, S], resp_mask [B, S], blp [B, S]) with the same
    conventions as the Rust DecodeState: mask covers response tokens incl.
    EOS; blp is the *untempered* logprob of each sampled token; rows are
    PAD-frozen after EOS.
    """
    from .configs import EOS, PAD

    b, p_len = prompt.shape
    s = cfg.seq_len
    cache, logits = prefill(cfg, flat, prompt)
    tokens0 = jnp.zeros((b, s), jnp.int32).at[:, :p_len].set(prompt)
    mask0 = jnp.zeros((b, s), jnp.float32)
    blp0 = jnp.zeros((b, s), jnp.float32)
    done0 = jnp.zeros((b,), bool)
    base_key = jax.random.PRNGKey(seed)

    def body(pos, carry):
        cache, logits, tokens, mask, blp, done = carry
        logp = jax.nn.log_softmax(logits, axis=-1)  # untempered, for blp
        key = jax.random.fold_in(base_key, pos)
        sampled = jax.random.categorical(
            key, logits / jnp.maximum(temperature, 1e-4), axis=-1
        )
        greedy = jnp.argmax(logits, axis=-1)
        tok = jnp.where(temperature > 0.0, sampled, greedy).astype(jnp.int32)
        tok = jnp.where(done, PAD, tok)
        tok_lp = jnp.take_along_axis(logp, tok[:, None], axis=1)[:, 0]
        live = (~done).astype(jnp.float32)
        tokens = jax.lax.dynamic_update_slice(tokens, tok[:, None], (0, pos))
        mask = jax.lax.dynamic_update_slice(mask, live[:, None], (0, pos))
        blp = jax.lax.dynamic_update_slice(
            blp, (tok_lp * live)[:, None], (0, pos)
        )
        done = done | (tok == EOS)
        logits, cache = decode_step(cfg, flat, cache, tok, pos)
        return cache, logits, tokens, mask, blp, done

    _, _, tokens, mask, blp, _ = jax.lax.fori_loop(
        p_len, s, body, (cache, logits, tokens0, mask0, blp0, done0)
    )
    return tokens, mask, blp
