"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: the Pallas implementations must
match them (pytest + hypothesis sweep in python/tests/test_kernel.py), and
model.py can be switched to them via `model.USE_REF_ATTENTION` to isolate
kernel bugs from model bugs.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def attention(q, k, v, causal=True, scale=None):
    """Reference scaled-dot-product attention.

    q, k, v: [B, H, S, Dh]. Returns [B, H, S, Dh] in q's dtype; softmax and
    accumulation are always f32 (matching the kernel's accumulators).
    """
    in_dtype = q.dtype
    q, k, v = (x.astype(jnp.float32) for x in (q, k, v))
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        s_q, s_k = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((s_q, s_k), dtype=bool), k=s_k - s_q)
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    return out.astype(in_dtype)


def attention_lse(q, k, v, causal=True, scale=None):
    """Reference log-sum-exp of the attention scores: [B, H, S].

    Matches the `lse` residual saved by the flash forward kernel.
    """
    q, k = q.astype(jnp.float32), k.astype(jnp.float32)
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        s_q, s_k = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((s_q, s_k), dtype=bool), k=s_k - s_q)
        scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)
    return m + jnp.log(jnp.sum(jnp.exp(scores - m[..., None]), axis=-1))
