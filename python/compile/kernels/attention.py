"""Flash-attention-style causal attention as Pallas kernels (L1 hot spot).

TPU-oriented design (DESIGN.md §4 Hardware-Adaptation): the CUDA
threadblock/shared-memory schedule of FlashAttention becomes an HBM↔VMEM
schedule expressed with BlockSpecs — the grid walks (batch*heads, q-blocks),
each grid cell streams K/V block-by-block through VMEM with running-softmax
(m, l) accumulators, and accumulation is always f32 (MXU-friendly tiles,
head_dim is a multiple of 32 in every config).

Kernels are lowered with `interpret=True`: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode (which lowers to plain HLO) is the
execution path; real-TPU efficiency is estimated statically in
EXPERIMENTS.md §Perf from the VMEM footprint of these BlockSpecs.

The backward pass is implemented as two more Pallas kernels (dq, and dk/dv)
wired up through `jax.custom_vjp`, recomputing attention probabilities from
the saved (out, lse) residuals exactly like FlashAttention's backward.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Default block sizes. Sequence lengths here are small (<=64) but the kernel
# is written for the general tiled case; tests sweep non-multiple shapes.
DEFAULT_BLOCK_Q = 16
DEFAULT_BLOCK_K = 16


def _ceil_div(a: int, b: int) -> int:
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------

def _masked_rows(x, pos, limit):
    """Zero rows whose absolute position is out of range.

    Interpret-mode Pallas pads out-of-bounds block reads with NaN; any
    ragged tail must be zeroed *at the load* because even `0 * NaN = NaN`
    would leak through the matmuls.
    """
    return jnp.where((pos < limit)[:, None], x, 0.0)


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, scale, causal,
                block_k, seq_q, seq_k):
    """One grid cell: one (batch*head, q-block). K/V streamed in blocks."""
    block_q = q_ref.shape[1]
    q_idx = pl.program_id(1)
    q_pos = q_idx * block_q + jax.lax.iota(jnp.int32, block_q)  # absolute rows
    q = _masked_rows(q_ref[0].astype(jnp.float32), q_pos, seq_q) * scale

    num_kb = _ceil_div(seq_k, block_k)

    def body(kb, carry):
        acc, m_i, l_i = carry
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        k = pl.load(k_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        k = _masked_rows(k.astype(jnp.float32), k_pos, seq_k)
        v = _masked_rows(v.astype(jnp.float32), k_pos, seq_k)
        s = q @ k.T  # [block_q, block_k]
        # Out-of-range K columns (ragged tail) are always masked; causal
        # masking compares absolute positions.
        valid = (k_pos < seq_k)[None, :]
        if causal:
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        # Mask p explicitly: for rows where every key so far is masked,
        # s - m_new == 0 and exp would wrongly give weight 1.
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        # alpha rescales the old accumulator; when both m_i and m_new are
        # still NEG_INF (nothing seen yet) the difference is 0 -> alpha 1,
        # which is harmless because acc and l are still zero.
        alpha = jnp.exp(jnp.minimum(m_i - m_new, 0.0))
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    d = q.shape[-1]
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))

    # Rows with no valid key (can't happen causally when q_pos>=0) keep l=0;
    # guard the division anyway so padded q-tails stay finite.
    l_safe = jnp.where(l_i > 0.0, l_i, 1.0)
    o_ref[0] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0] = m_i + jnp.log(l_safe)


def _flash_fwd(q, k, v, scale, causal, block_q, block_k, interpret):
    b, h, s_q, d = q.shape
    s_k = k.shape[2]
    bh = b * h
    qr = q.reshape(bh, s_q, d)
    kr = k.reshape(bh, s_k, d)
    vr = v.reshape(bh, s_k, d)
    block_q = min(block_q, s_q)
    block_k = min(block_k, s_k)
    grid = (bh, _ceil_div(s_q, block_q))
    out, lse = pl.pallas_call(
        functools.partial(
            _fwd_kernel, scale=scale, causal=causal,
            block_k=block_k, seq_q=s_q, seq_k=s_k,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s_k, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s_k, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s_q, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s_q), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s_q, d), lse.reshape(b, h, s_q)


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   *, scale, causal, block_k, seq_q, seq_k):
    """dq for one (bh, q-block): stream K/V blocks, recompute p from lse."""
    block_q = q_ref.shape[1]
    q_idx = pl.program_id(1)
    q_pos = q_idx * block_q + jax.lax.iota(jnp.int32, block_q)
    row_ok = q_pos < seq_q
    q = _masked_rows(q_ref[0].astype(jnp.float32), q_pos, seq_q)
    do = _masked_rows(do_ref[0].astype(jnp.float32), q_pos, seq_q)
    lse = jnp.where(row_ok, lse_ref[0], 0.0)
    delta = jnp.where(row_ok, delta_ref[0], 0.0)
    num_kb = _ceil_div(seq_k, block_k)

    def body(kb, dq):
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        k = pl.load(k_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        k = _masked_rows(k.astype(jnp.float32), k_pos, seq_k)
        v = _masked_rows(v.astype(jnp.float32), k_pos, seq_k)
        s = (q * scale) @ k.T
        valid = (k_pos < seq_k)[None, :] & row_ok[:, None]
        if causal:
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        s = jnp.where(valid, s, NEG_INF)
        p = jnp.where(valid, jnp.exp(s - lse[:, None]), 0.0)
        dp = do @ v.T
        ds = p * (dp - delta[:, None]) * scale
        return dq + ds @ k

    dq = jax.lax.fori_loop(
        0, num_kb, body, jnp.zeros_like(q, dtype=jnp.float32)
    )
    dq_ref[0] = dq.astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, scale, causal, block_q, seq_q,
                    seq_k):
    """dk/dv for one (bh, k-block): stream q-blocks."""
    block_k = k_ref.shape[1]
    k_idx = pl.program_id(1)
    k_pos = k_idx * block_k + jax.lax.iota(jnp.int32, block_k)
    k = _masked_rows(k_ref[0].astype(jnp.float32), k_pos, seq_k)
    v = _masked_rows(v_ref[0].astype(jnp.float32), k_pos, seq_k)
    num_qb = _ceil_div(seq_q, block_q)

    def body(qb, carry):
        dk, dv = carry
        q_pos = qb * block_q + jax.lax.iota(jnp.int32, block_q)
        row_ok = q_pos < seq_q
        qs = (0, pl.dslice(qb * block_q, block_q), slice(None))
        q = _masked_rows(pl.load(q_ref, qs).astype(jnp.float32), q_pos, seq_q)
        do = _masked_rows(
            pl.load(do_ref, qs).astype(jnp.float32), q_pos, seq_q
        )
        ls = (0, pl.dslice(qb * block_q, block_q))
        lse = jnp.where(row_ok, pl.load(lse_ref, ls), 0.0)
        delta = jnp.where(row_ok, pl.load(delta_ref, ls), 0.0)
        s = (q * scale) @ k.T  # [block_q, block_k]
        valid = row_ok[:, None] & (k_pos < seq_k)[None, :]
        if causal:
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        s = jnp.where(valid, s, NEG_INF)
        p = jnp.where(valid, jnp.exp(s - lse[:, None]), 0.0)
        dv = dv + p.T @ do
        dp = do @ v.T
        ds = p * (dp - delta[:, None]) * scale
        dk = dk + ds.T @ q
        return dk, dv

    dk0 = jnp.zeros_like(k, dtype=jnp.float32)
    dv0 = jnp.zeros_like(v, dtype=jnp.float32)
    dk, dv = jax.lax.fori_loop(0, num_qb, body, (dk0, dv0))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _flash_bwd(q, k, v, out, lse, do, scale, causal, block_q, block_k,
               interpret):
    b, h, s_q, d = q.shape
    s_k = k.shape[2]
    bh = b * h
    qr, kr, vr = (x.reshape(bh, -1, d) for x in (q, k, v))
    dor = do.reshape(bh, s_q, d)
    lser = lse.reshape(bh, s_q)
    # delta_i = rowsum(dO_i * O_i), the standard flash-bwd residual.
    delta = jnp.sum(
        do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
    ).reshape(bh, s_q)

    block_q = min(block_q, s_q)
    block_k = min(block_k, s_k)

    dq = pl.pallas_call(
        functools.partial(
            _bwd_dq_kernel, scale=scale, causal=causal,
            block_k=block_k, seq_q=s_q, seq_k=s_k,
        ),
        grid=(bh, _ceil_div(s_q, block_q)),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s_k, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s_k, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_q, d), q.dtype),
        interpret=interpret,
    )(qr, kr, vr, dor, lser, delta)

    dk, dv = pl.pallas_call(
        functools.partial(
            _bwd_dkv_kernel, scale=scale, causal=causal,
            block_q=block_q, seq_q=s_q, seq_k=s_k,
        ),
        grid=(bh, _ceil_div(s_k, block_k)),
        in_specs=[
            pl.BlockSpec((1, s_q, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s_q, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s_q), lambda i, j: (i, 0)),
            pl.BlockSpec((1, s_q), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s_k, d), k.dtype),
            jax.ShapeDtypeStruct((bh, s_k, d), v.dtype),
        ],
        interpret=interpret,
    )(qr, kr, vr, dor, lser, delta)

    return (
        dq.reshape(b, h, s_q, d),
        dk.reshape(b, h, s_k, d),
        dv.reshape(b, h, s_k, d),
    )


# ---------------------------------------------------------------------------
# Public API: differentiable flash attention
# ---------------------------------------------------------------------------

@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def flash_attention(q, k, v, causal=True, scale=None,
                    block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                    interpret=True):
    """Tiled causal attention. q, k, v: [B, H, S, Dh] -> [B, H, S, Dh].

    `scale` defaults to 1/sqrt(Dh). Differentiable via the flash backward
    kernels. `interpret=True` is the CPU-PJRT execution path (see module
    docstring).
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    out, _ = _flash_fwd(q, k, v, scale, causal, block_q, block_k, interpret)
    return out


def _vjp_fwd(q, k, v, causal, scale, block_q, block_k, interpret):
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    out, lse = _flash_fwd(q, k, v, scale, causal, block_q, block_k, interpret)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, scale, block_q, block_k, interpret, res, do):
    q, k, v, out, lse = res
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    dq, dk, dv = _flash_bwd(
        q, k, v, out, lse, do, scale, causal, block_q, block_k, interpret
    )
    return dq, dk, dv


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)


def attention_lse(q, k, v, causal=True, scale=None,
                  block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                  interpret=True):
    """Expose the forward kernel's log-sum-exp residual (for tests)."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    _, lse = _flash_fwd(q, k, v, scale, causal, block_q, block_k, interpret)
    return lse
