//! Math & reasoning example (paper §5.2): RL with a rule-based exact-match
//! reward on the GSM8k-analogue arithmetic task — no reward model at all.
//!
//! Trains sync and async Online DPO from the same SFT checkpoint, reports
//! pass@1 (greedy exact-match) and the async speedup, and prints a few
//! solved/unsolved problems.
//!
//! ```sh
//! make artifacts && cargo run --release --example math_gsm
//! ```

use async_rlhf::config::{Algo, ExpConfig, Mode};
use async_rlhf::coordinator;
use async_rlhf::eval::evaluate;
use async_rlhf::gen::{cached::CachedEngine, Generator, SampleOpts};
use async_rlhf::runtime::ParamView;
use async_rlhf::tokenizer::detok;
use async_rlhf::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::var("ASYNC_RLHF_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);
    let base = ExpConfig {
        model: "math_s".into(),
        algo: Algo::Dpo,
        steps,
        rm_steps: 0, // rule reward: no RM (paper §5.2)
        eval_prompts: 128,
        run_dir: "runs/math_example".into(),
        ..ExpConfig::default()
    };

    println!("== GSM8k-analogue math RL ({} steps) ==", steps);
    let prep = coordinator::prepare(&base, true)?;

    let sft_eval = evaluate(
        &prep.engine, &prep.sft_params, &prep.sft_params, &prep.taskgen,
        base.eval_prompts, base.temperature, base.seed,
    )?;
    println!("SFT pass@1: {:.1}%", sft_eval.pass1 * 100.0);

    let mut results = Vec::new();
    for mode in [Mode::Sync, Mode::Async] {
        let mut cfg = base.clone();
        cfg.mode = mode;
        println!("\n--- {} Online DPO ---", mode.name());
        let out = coordinator::run(&cfg, &prep, true)?;
        let ev = evaluate(
            &prep.engine, &out.final_params, &prep.sft_params, &prep.taskgen,
            cfg.eval_prompts, cfg.temperature, cfg.seed,
        )?;
        println!(
            "{}: pass@1 {:.1}%  ppl {:.4}  wall {:.1}s",
            mode.name(),
            ev.pass1 * 100.0,
            ev.kl_ppl,
            out.timeline.wall()
        );
        results.push((mode, ev.pass1, out.timeline.wall(), out.final_params));
    }

    if let [(_, sp, sw, _), (_, ap, aw, final_params)] = &results[..] {
        println!("\nTable-2-style summary:");
        println!("  Sync  Online DPO: pass@1 {:.1}%  {sw:.1}s", sp * 100.0);
        println!(
            "  Async Online DPO: pass@1 {:.1}%  {aw:.1}s ({:+.1}% faster)",
            ap * 100.0,
            (sw / aw - 1.0) * 100.0
        );

        // show a few worked problems (greedy decode)
        let cfgm = prep.engine.manifest.config.clone();
        let examples = prep.taskgen.batch(10_000_000, cfgm.gen_batch);
        let prompts: Vec<Vec<i32>> =
            examples.iter().map(|e| e.prompt.clone()).collect();
        let mut rng = Pcg32::new(0, 0);
        let gen = CachedEngine.generate(
            &prep.engine, ParamView::fresh(final_params), &prompts,
            SampleOpts { temperature: 0.7, greedy: true }, &mut rng,
        )?;
        println!("\nsample problems (greedy):");
        for i in 0..4 {
            let resp = gen.response(i, cfgm.prompt_len);
            let correct = async_rlhf::reward::gold::score(&examples[i].meta, resp) >= 1.0;
            println!(
                "  {} -> {}   [{}]",
                detok(&examples[i].prompt[..examples[i].prompt.iter()
                    .position(|&t| t == async_rlhf::tokenizer::PAD)
                    .unwrap_or(examples[i].prompt.len())]),
                detok(resp),
                if correct { "correct" } else { "wrong" }
            );
        }
    }
    Ok(())
}
