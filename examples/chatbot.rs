//! Chatbot example (paper §5.1): instruction-following RLHF on the
//! No-Robots-analogue task — train async Online DPO, then chat with the
//! model on held-out instructions and report the GPT-4o-judge-analogue
//! (gold) win-rate against references.
//!
//! ```sh
//! make artifacts && cargo run --release --example chatbot
//! ```

use async_rlhf::config::{Algo, ExpConfig, Mode};
use async_rlhf::coordinator;
use async_rlhf::eval::evaluate;
use async_rlhf::gen::{cached::CachedEngine, Generator, SampleOpts};
use async_rlhf::runtime::ParamView;
use async_rlhf::tokenizer::detok;
use async_rlhf::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::var("ASYNC_RLHF_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);
    let cfg = ExpConfig {
        model: "chat_m".into(),
        algo: Algo::Dpo,
        mode: Mode::Async,
        steps,
        eval_prompts: 96,
        run_dir: "runs/chatbot_example".into(),
        ..ExpConfig::default()
    };

    println!("== chatbot RLHF (chat_m, async Online DPO, {steps} steps) ==");
    let prep = coordinator::prepare(&cfg, true)?;
    let sft_eval = evaluate(
        &prep.engine, &prep.sft_params, &prep.sft_params, &prep.taskgen,
        cfg.eval_prompts, cfg.temperature, cfg.seed,
    )?;
    println!(
        "SFT: win-rate {:.1}% (len {:.1})",
        sft_eval.win_rate * 100.0,
        sft_eval.mean_len
    );

    let out = coordinator::run(&cfg, &prep, true)?;
    let ev = evaluate(
        &prep.engine, &out.final_params, &prep.sft_params, &prep.taskgen,
        cfg.eval_prompts, cfg.temperature, cfg.seed,
    )?;
    println!(
        "\nasync Online DPO: win-rate {:.1}% (len {:.1}), kl-ppl {:.4}, \
         wall {:.1}s",
        ev.win_rate * 100.0,
        ev.mean_len,
        ev.kl_ppl,
        out.timeline.wall()
    );

    // "chat" with the model on held-out instructions
    let mcfg = prep.engine.manifest.config.clone();
    let examples = prep.taskgen.batch(10_000_000, mcfg.gen_batch);
    let prompts: Vec<Vec<i32>> =
        examples.iter().map(|e| e.prompt.clone()).collect();
    let mut rng = Pcg32::new(3, 0);
    let gen = CachedEngine.generate(
        &prep.engine, ParamView::fresh(&out.final_params), &prompts,
        SampleOpts::default(), &mut rng,
    )?;
    println!("\nheld-out conversations:");
    for i in 0..5 {
        let resp = gen.response(i, mcfg.prompt_len);
        println!("  user     : {}", detok(&examples[i].prompt));
        println!("  assistant: {}", detok(resp));
        println!("  reference: {}", detok(&examples[i].reference));
        println!();
    }
    Ok(())
}
