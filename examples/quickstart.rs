//! Quickstart: the whole stack in ~60 lines.
//!
//! Loads the compiled `dev` bundle, warm-starts with SFT, runs a handful of
//! *asynchronous* Online DPO steps (generation worker thread + trainer),
//! and prints before/after samples.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use async_rlhf::config::{Algo, ExpConfig, Mode};
use async_rlhf::coordinator;
use async_rlhf::eval::evaluate;
use async_rlhf::gen::{cached::CachedEngine, Generator, SampleOpts};
use async_rlhf::runtime::ParamView;
use async_rlhf::tokenizer::detok;
use async_rlhf::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let cfg = ExpConfig {
        model: "dev".into(),
        algo: Algo::Dpo,
        mode: Mode::Async,
        steps: 24,
        lr: 1e-3,
        eval_prompts: 32,
        run_dir: std::env::temp_dir().join("async_rlhf_quickstart"),
        ..ExpConfig::default()
    };

    println!("== async-rlhf quickstart (config: {}) ==", cfg.model);
    let prep = coordinator::prepare(&cfg, true)?;

    // peek at the SFT policy's behaviour
    let examples = prep.taskgen.batch(10_000_000, prep.engine.manifest.config.gen_batch);
    let prompts: Vec<Vec<i32>> = examples.iter().map(|e| e.prompt.clone()).collect();
    let mut rng = Pcg32::new(0, 0);
    let before = CachedEngine.generate(
        &prep.engine, ParamView::fresh(&prep.sft_params), &prompts,
        SampleOpts::default(), &mut rng,
    )?;

    println!("\ntraining: {} steps of one-step off-policy async Online DPO ...", cfg.steps);
    let out = coordinator::run(&cfg, &prep, true)?;
    println!(
        "done in {:.1}s ({} episodes). mean staleness: {} (one-step off-policy)",
        out.timeline.wall(),
        out.episodes,
        out.log.meta.get("mean_staleness").cloned().unwrap_or_default()
    );

    let mut rng = Pcg32::new(0, 0);
    let after = CachedEngine.generate(
        &prep.engine, ParamView::fresh(&out.final_params), &prompts,
        SampleOpts::default(), &mut rng,
    )?;

    let p = prep.engine.manifest.config.prompt_len;
    println!("\nsample responses (before -> after RLHF):");
    for i in 0..3 {
        println!("  prompt : {}", detok(&examples[i].prompt));
        println!("  ref    : {}", detok(&examples[i].reference));
        println!("  before : {}", detok(before.response(i, p)));
        println!("  after  : {}", detok(after.response(i, p)));
    }

    let ev_sft = evaluate(&prep.engine, &prep.sft_params, &prep.sft_params,
                          &prep.taskgen, 32, 0.7, 1)?;
    let ev_rl = evaluate(&prep.engine, &out.final_params, &prep.sft_params,
                         &prep.taskgen, 32, 0.7, 1)?;
    println!("\ngold win-rate vs references: SFT {:.1}% -> RLHF {:.1}%",
             ev_sft.win_rate * 100.0, ev_rl.win_rate * 100.0);
    println!("KL (SFT ppl on samples)    : {:.4} -> {:.4}",
             ev_sft.kl_ppl, ev_rl.kl_ppl);
    Ok(())
}
