//! End-to-end driver (DESIGN.md deliverable): the full TLDR pipeline on a
//! real (small) workload, proving all three layers compose.
//!
//! Pipeline: SFT on synthetic TLDR demonstrations -> proxy RM on
//! gold-labelled preference pairs -> RLHF with Online DPO, run BOTH
//! synchronously and asynchronously on the same SFT/RM checkpoints —
//! logging win-rate and KL curves, then comparing final performance and
//! wall-clock (the paper's Fig 1 protocol at one scale).
//!
//! ```sh
//! make artifacts
//! cargo run --release --example tldr_async            # tldr_s, 96 steps
//! ASYNC_RLHF_MODEL=tldr_m ASYNC_RLHF_STEPS=256 cargo run --release --example tldr_async
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use async_rlhf::config::{Algo, ExpConfig, Mode};
use async_rlhf::coordinator;
use async_rlhf::eval::evaluate;
use async_rlhf::metrics::Phase;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("ASYNC_RLHF_MODEL").unwrap_or_else(|_| "tldr_s".into());
    let steps: u64 = std::env::var("ASYNC_RLHF_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);

    let base = ExpConfig {
        model: model.clone(),
        algo: Algo::Dpo,
        steps,
        eval_prompts: 128,
        run_dir: "runs/tldr_async_example".into(),
        ..ExpConfig::default()
    };

    println!("== end-to-end TLDR RLHF ({model}, {steps} steps) ==");
    let prep = coordinator::prepare(&base, true)?;
    println!(
        "model: {} params, gen_batch {}, pairs {}",
        prep.engine.manifest.param_count,
        prep.engine.manifest.config.gen_batch,
        prep.engine.manifest.config.train_pairs
    );

    // SFT baseline row (paper Table 3)
    let sft_eval = evaluate(
        &prep.engine, &prep.sft_params, &prep.sft_params, &prep.taskgen,
        base.eval_prompts, base.temperature, base.seed,
    )?;
    println!(
        "SFT baseline: win-rate {:.1}%, ppl {:.4}",
        sft_eval.win_rate * 100.0,
        sft_eval.kl_ppl
    );

    let mut finals = Vec::new();
    for mode in [Mode::Sync, Mode::Async] {
        let mut cfg = base.clone();
        cfg.mode = mode;
        println!("\n--- {} Online DPO ---", mode.name());
        let out = coordinator::run(&cfg, &prep, true)?;
        let ev = evaluate(
            &prep.engine, &out.final_params, &prep.sft_params, &prep.taskgen,
            cfg.eval_prompts, cfg.temperature, cfg.seed,
        )?;
        let totals = out.timeline.totals();
        println!(
            "{}: win-rate {:.1}%  kl-ppl {:.4}  wall {:.1}s \
             (gen {:.1}s, score {:.1}s, train {:.1}s)",
            mode.name(),
            ev.win_rate * 100.0,
            ev.kl_ppl,
            out.timeline.wall(),
            totals.get(&Phase::Generate).unwrap_or(&0.0),
            totals.get(&Phase::Score).unwrap_or(&0.0),
            totals.get(&Phase::Train).unwrap_or(&0.0),
        );
        // persist the loss/win-rate curves
        let dir = cfg.run_dir.join(cfg.label());
        out.log.save(&dir, "train")?;
        println!("curves: {}/train.csv", dir.display());
        finals.push((mode, ev, out.timeline.wall()));
    }

    if let [(_, sync_ev, sync_wall), (_, async_ev, async_wall)] = &finals[..] {
        println!("\n== Fig-1-style summary ({model}) ==");
        println!(
            "sync : win {:.1}%  wall {:.1}s",
            sync_ev.win_rate * 100.0,
            sync_wall
        );
        println!(
            "async: win {:.1}%  wall {:.1}s  ({:+.1}% speed)",
            async_ev.win_rate * 100.0,
            async_wall,
            (sync_wall / async_wall - 1.0) * 100.0
        );
        println!(
            "paper-shape: async matches sync win-rate [{}], async faster [{}]",
            if (sync_ev.win_rate - async_ev.win_rate).abs() < 0.08 {
                "OK"
            } else {
                "DIVERGED"
            },
            if async_wall < sync_wall { "OK" } else { "SLOWER" }
        );
    }
    Ok(())
}
