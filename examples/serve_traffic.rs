//! Serve-while-training on a replayed traffic trace: live sessions as
//! the prompt stream (ROADMAP "Serving front-end").
//!
//! A deterministic load generator replays multi-turn sessions onto the
//! continuous slot pool; completed turns stream back into the trainer
//! as Online DPO rounds, and every decode sweep reads the latest
//! published params. The run's length comes from the trace, not
//! `--steps`. Afterwards the example prints the serving telemetry
//! (TTFT / time-to-retire percentiles, served-params staleness, slot
//! occupancy vs the fixed-round counterfactual) and the usual
//! win-rate/KL eval against the SFT baseline.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example serve_traffic          # tldr_s, 32 sessions
//! ASYNC_RLHF_SESSIONS=64 ASYNC_RLHF_RATE=2.0 \
//!     cargo run --release --example serve_traffic
//! ```
//!
//! Geometry note: `sessions * turns * k` must tile into whole
//! `gen_batch` rounds (`serve::derive_steps` rejects anything else
//! loudly) — with tldr_s's gen_batch 32 and k 2, 32 sessions x 2 turns
//! is exactly 4 optimizer steps.

use async_rlhf::config::{Algo, ExpConfig, GenEngine, Mode};
use async_rlhf::coordinator;
use async_rlhf::eval::evaluate;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let model =
        std::env::var("ASYNC_RLHF_MODEL").unwrap_or_else(|_| "tldr_s".into());
    let sessions: u64 = env_or("ASYNC_RLHF_SESSIONS", 32);
    let turns: u64 = env_or("ASYNC_RLHF_TURNS", 2);
    let rate: f64 = env_or("ASYNC_RLHF_RATE", 1.0);

    let cfg = ExpConfig {
        model: model.clone(),
        algo: Algo::Dpo,
        mode: Mode::Serve,
        gen_engine: GenEngine::Continuous,
        serve_sessions: sessions,
        serve_turns: turns,
        arrival_rate: rate,
        eval_prompts: 128,
        run_dir: "runs/serve_traffic_example".into(),
        ..ExpConfig::default()
    };

    println!(
        "== serve-while-training ({model}, {sessions} sessions x {turns} \
         turns, rate {rate}/sweep) =="
    );
    let prep = coordinator::prepare(&cfg, true)?;
    let out = coordinator::run(&cfg, &prep, true)?;

    println!(
        "\nserved {} sessions x {} turns over {} worker(s):",
        sessions, turns, cfg.gen_workers
    );
    for key in [
        "serve_requests",
        "serve_tokens",
        "serve_ttft_p50",
        "serve_ttft_p99",
        "serve_retire_p50",
        "serve_retire_p99",
        "serve_lag_p50",
        "serve_lag_p99",
        "serve_lag_max",
        "serve_occupancy",
        "serve_occupancy_round_tier",
    ] {
        if let Some(v) = out.log.meta.get(key) {
            println!("  {key:<26} {v}");
        }
    }

    let ev = evaluate(
        &prep.engine,
        &out.final_params,
        &prep.sft_params,
        &prep.taskgen,
        cfg.eval_prompts,
        cfg.temperature,
        cfg.seed,
    )?;
    println!(
        "\ntrained on the traffic: win-rate {:.1}%  kl-ppl {:.4}  \
         wall {:.1}s for {} episodes",
        ev.win_rate * 100.0,
        ev.kl_ppl,
        out.timeline.wall(),
        out.episodes
    );
    let dir = cfg.run_dir.join(cfg.label());
    out.log.save(&dir, "serve")?;
    println!("curves: {}/serve.csv", dir.display());
    Ok(())
}
