//! Off-policyness sweep demo (paper §3.2-3.3 in miniature): run Online DPO
//! and PPO at N ∈ {1, 4, 16} mini-batches per generation round and watch
//! DPO stay robust while PPO degrades.
//!
//! ```sh
//! make artifacts && cargo run --release --example offpolicy_sweep
//! ```

use async_rlhf::config::{Algo, ExpConfig};
use async_rlhf::coordinator;
use async_rlhf::eval::evaluate;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::var("ASYNC_RLHF_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let base = ExpConfig {
        model: "tldr_s".into(),
        steps,
        eval_prompts: 96,
        run_dir: "runs/offpolicy_example".into(),
        ..ExpConfig::default()
    };

    println!("== off-policyness sweep (tldr_s, {steps} steps/run) ==");
    let prep = coordinator::prepare(&base, true)?;

    println!(
        "\n{:<6} {:>4} {:>10} {:>9} {:>9}",
        "algo", "N", "win_rate", "kl_ppl", "gold"
    );
    for algo in [Algo::Dpo, Algo::Ppo] {
        for n in [1usize, 4, 16] {
            let mut cfg = base.clone();
            cfg.algo = algo;
            cfg.n_minibatches = n;
            let out = coordinator::run(&cfg, &prep, false)?;
            let ev = evaluate(
                &prep.engine, &out.final_params, &prep.sft_params,
                &prep.taskgen, cfg.eval_prompts, cfg.temperature, cfg.seed,
            )?;
            println!(
                "{:<6} {:>4} {:>9.1}% {:>9.4} {:>9.3}",
                algo.name(),
                n,
                ev.win_rate * 100.0,
                ev.kl_ppl,
                ev.mean_gold
            );
        }
    }
    println!(
        "\npaper shape (Fig 4): DPO's rows stay clustered as N grows; \
         PPO's win-rate drops."
    );
    Ok(())
}
