#!/usr/bin/env bash
# CI gate: style checks alongside the tier-1 build+test pass.
#
#   ./ci.sh          # fmt + clippy + build + test
#   ./ci.sh --fast   # skip the release build (style + debug tests only)
#
# Runs from the repo root; the crate lives under rust/. Benches emit
# machine-readable perf snapshots (BENCH_hot_path.json, BENCH_gen_speed.json,
# BENCH_staleness.json, BENCH_bound_analysis.json, BENCH_step_overlap.json,
# BENCH_serving.json, BENCH_shard_scale.json) when artifacts are present —
# build them first with `python -m compile.aot` if you want the perf
# trajectory recorded.

set -euo pipefail
cd "$(dirname "$0")"
# the crate manifest lives with the sources under rust/ (fall back to the
# repo root if a workspace manifest is ever added there)
if [[ ! -f Cargo.toml && -f rust/Cargo.toml ]]; then
  cd rust
fi
if [[ ! -f Cargo.toml ]]; then
  echo "error: no Cargo.toml at repo root or rust/ — source-only checkout," >&2
  echo "       the cargo gate needs the crate manifest first" >&2
  exit 1
fi

echo "== style gate =="
cargo fmt --check
cargo clippy --all-targets -- -D warnings

echo "== tier-1 =="
if [[ "${1:-}" != "--fast" ]]; then
  cargo build --release
  # benches are part of the gate: they emit the BENCH_*.json perf
  # snapshots (hot_path, gen_speed, staleness), so letting them rot
  # would silently drop the trajectory
  cargo build --benches --release
fi
cargo test -q

echo "== invariant gates (staleness, pair gather, continuous, faults, serving, shard, failover) =="
# the pipeline's staleness-bound tests, the pair-gather equivalence /
# byte-counter tests, the continuous-pool slot-lifecycle tests, the
# fault-injection / checkpoint-resume tests, the serving front-end
# tests, the sharded-trainer equivalence/bound tests, and the
# failover tests (lane takeover + session migration) are
# release-gating and already ran in the full `cargo test -q`
# above; here just assert they still EXIST (cargo exits 0 on a
# zero-match filter, so a rename/module move would otherwise drop the
# gate silently) — --list doesn't re-run anything
for filter in staleness bounded_queue pair_gather continuous fault resume serving shard takeover migrate; do
  # capture first: grep -q on the pipe would EPIPE cargo under pipefail
  listing=$(cargo test -q "$filter" -- --list 2>/dev/null)
  echo "$listing" | grep -q ": test" || {
    echo "error: no tests match filter '$filter' — staleness gate dropped" >&2
    exit 1
  }
done
